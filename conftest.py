# Repo-root conftest: its presence makes pytest prepend this directory to
# sys.path, so `import benchmarks.*` works under a bare `pytest` invocation
# (not only `python -m pytest`, which prepends the CWD itself).


def pytest_configure(config):
    # CI's tier-1 job runs `-m "not slow"`; the full randomized suites
    # stay runnable locally with a bare `pytest`.
    config.addinivalue_line(
        "markers",
        "slow: long randomized suites (excluded from CI tier-1 via "
        '-m "not slow")')
