# Repo-root conftest: its presence makes pytest prepend this directory to
# sys.path, so `import benchmarks.*` works under a bare `pytest` invocation
# (not only `python -m pytest`, which prepends the CWD itself).
