"""Tentpole measurement: microbatch pipelining hides the disaggregation hop.

Sweeps stage count x microbatch count over a stage chain whose per-stage
compute and per-hop transfer are calibrated 1:1 (``ratio=1``) — the worst
case for a serial data plane, where half of every batch's wall time is the
wire. Transfer cost comes from ``MetaAccelerator``'s ``LinkModel``
(ExpEther-class edge emulated on the local bus, paper §2: ~20% of PCIe);
compute is a calibrated device-busy stall plus a real jnp op so activations
actually flow through the sub-slices and bit-exactness stays checkable.

Per (S, k) configuration, reports measured pipeline time against two
anchors (DESIGN.md §5):

  serial_s    measured ``microbatches=1`` run — the serial lower bound
              sum(compute) + sum(transfer) paid on the critical path
  ideal_s     fill/drain-aware pipeline bound over the R = 2S resources:
              (sum_i(c_i + t_i) + (k-1) * max_r tau_r) / k

``python -m benchmarks.pipeline_overlap`` writes BENCH_pipeline.json so
the overlap speedup is tracked across PRs (benchmarks/check_regression.py
gates on it)."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import DevicePool
from repro.core.meta_accel import LinkModel, MetaAccelerator, StageSpec


def _make_stage(i: int, compute_s: float, batch: int) -> StageSpec:
    def fn(slice_, x):
        # device-busy stall scaled to the microbatch's share of the batch,
        # then a real op so the activation buffer is produced on-slice
        time.sleep(compute_s * x.shape[0] / batch)
        return x + 1.0

    return StageSpec(name=f"s{i}", kind=None, n_devices=1,
                     mesh_shape=(1, 1), axis_names=("data", "model"),
                     stage_fn=fn)


def bench(stage_counts=(2, 4), microbatches=(1, 2, 4, 8), batch=64,
          feat=256, compute_s=0.05, ratio=1.0, iters=2, json_path=None):
    import jax

    dev = jax.devices()[0]
    nbytes_full = batch * feat * 4
    transfer_s = compute_s * ratio
    link = LinkModel(gbytes_per_s=nbytes_full / transfer_s / 1e9)
    x = np.ones((batch, feat), np.float32)
    rows = []
    record = {"bench": "pipeline_overlap", "batch": batch, "feat": feat,
              "compute_s": compute_s, "transfer_to_compute": ratio,
              "sweep": {}}

    for S in stage_counts:
        pool = DevicePool.virtual(S, devices_per_node=1)
        for d in pool._devices:
            d.device = dev
        meta = MetaAccelerator(pool, link=link)
        stages = [_make_stage(i, compute_s, batch) for i in range(S)]
        slices = meta.allocate(stages)
        try:
            # warm every chunk shape so eager-op compiles (~77ms each on
            # this host) never land inside a timed region
            for k in microbatches:
                meta.run_pipeline(stages, slices, x, microbatches=k)

            def timed(k):
                best, out = 1e9, None
                for _ in range(iters):
                    before = meta.transfer_totals()
                    t0 = time.perf_counter()
                    out = meta.run_pipeline(stages, slices, x,
                                            microbatches=k)
                    best = min(best, time.perf_counter() - t0)
                    after = meta.transfer_totals()
                    moved = after["bytes"] - before["bytes"]
                    assert moved == S * nbytes_full, (
                        f"hop accounting drifted: {moved} != "
                        f"{S * nbytes_full}")
                return best, out

            serial_s, ref = timed(1)
            record["sweep"][f"s{S}_k1"] = {"measured_s": serial_s,
                                           "bytes_per_run": S * nbytes_full}
            rows.append((f"pipeline/overlap_s{S}_k1",
                         f"{serial_s * 1e6:.0f}", "serial_baseline"))
            for k in microbatches:
                if k <= 1:
                    continue
                measured_s, out = timed(k)
                exact = np.array_equal(np.asarray(ref), np.asarray(out))
                per_stage = compute_s + transfer_s
                ideal_s = (S * per_stage
                           + (k - 1) * max(compute_s, transfer_s)) / k
                speedup = serial_s / measured_s
                eff = ideal_s / measured_s
                record["sweep"][f"s{S}_k{k}"] = {
                    "measured_s": measured_s, "serial_s": serial_s,
                    "ideal_s": ideal_s, "speedup": speedup,
                    "efficiency": eff, "bit_exact": exact,
                    "microbatches": k, "stages": S,
                }
                rows.append((f"pipeline/overlap_s{S}_k{k}",
                             f"{measured_s * 1e6:.0f}",
                             f"speedup={speedup:.2f}x eff={eff:.2f} "
                             f"exact={exact}"))
        finally:
            meta.release(slices)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=2)
    return rows


if __name__ == "__main__":
    out = os.path.join(os.path.dirname(__file__), "..",
                       "BENCH_pipeline.json")
    for r in bench(json_path=os.path.abspath(out)):
        print(",".join(str(x) for x in r))
