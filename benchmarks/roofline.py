"""Roofline table — reads the dry-run artifacts (results/*.jsonl) and
renders the per-(arch x shape x mesh) terms for EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def load(name):
    path = os.path.join(RESULTS, name)
    by_cell = {}
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                r = json.loads(line)
                by_cell[(r["arch"], r["shape"])] = r  # keep-last (re-runs)
    return list(by_cell.values())


def render_table(recs):
    hdr = (f"| {'arch':24s} | {'shape':11s} | {'mesh':7s} | {'strat':9s} "
           f"| {'compute':>9s} | {'memory':>9s} | {'coll':>9s} "
           f"| {'bound':10s} | {'MFU':>6s} | {'GB/dev':>7s} |")
    sep = "|" + "-" * (len(hdr) - 2) + "|"
    lines = [hdr, sep]
    for r in recs:
        if r.get("status") == "skip":
            lines.append(
                f"| {r['arch']:24s} | {r['shape']:11s} | {r['mesh']:7s} "
                f"| {'—':9s} | {'SKIP':>9s} | {r['reason']:>9s} |")
            continue
        if r.get("status") != "ok":
            lines.append(
                f"| {r['arch']:24s} | {r['shape']:11s} | {r['mesh']:7s} "
                f"| {'—':9s} | {'ERROR':>9s} |")
            continue
        peak = (r.get("memory") or {}).get("peak_bytes", 0) / 1e9
        lines.append(
            f"| {r['arch']:24s} | {r['shape']:11s} | {r['mesh']:7s} "
            f"| {r.get('strategy', '?'):9s} "
            f"| {r['compute_s'] * 1e3:8.1f}ms | {r['memory_s'] * 1e3:8.1f}ms "
            f"| {r['collective_s'] * 1e3:8.1f}ms | {r['dominant']:10s} "
            f"| {r['mfu'] * 100:5.1f}% | {peak:7.2f} |")
    return "\n".join(lines)


def bench():
    rows = []
    for name, label in (("dryrun_single.jsonl", "16x16"),
                        ("dryrun_multi.jsonl", "2x16x16")):
        recs = load(name)
        ok = [r for r in recs if r.get("status") == "ok"]
        if not ok:
            continue
        for r in ok:
            rows.append((
                f"roofline/{label}/{r['arch']}/{r['shape']}",
                r["step_time_s"] * 1e6,
                f"bound={r['dominant']};mfu={r['mfu']:.3f}"))
    if not rows:
        rows.append(("roofline/no_artifacts", 0.0, "run dryrun first"))
    return rows


if __name__ == "__main__":
    for mesh_file in ("dryrun_single.jsonl", "dryrun_multi.jsonl"):
        recs = load(mesh_file)
        if recs:
            print(f"\n=== {mesh_file} ===")
            print(render_table(recs))
