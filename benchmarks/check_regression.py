"""CI gate: fail when a fresh benchmark run regresses more than ``slack``x
against the committed BENCH_*.json records.

Gated rows (only metrics present in both the committed record and the
fresh run are compared — a machine that skips a size is not a failure):

  sched/acquire_<n>        BENCH_sched.json    sizes[n].indexed_us_per_op
                           (lower is better)
  pipeline/overlap_<cfg>   BENCH_pipeline.json sweep[cfg].speedup
                           (higher is better; k=1 baselines not gated)
  preempt/speedup_<n>      BENCH_preempt.json  pools[n].speedup
                           (higher is better; capped at record time)
  defrag/largest_run_ratio_<n>  BENCH_preempt.json  defrag[n]
                           .largest_run_ratio (higher is better)
  serve/speedup_<w>        BENCH_serve.json    workloads[w].speedup
                           (higher is better; continuous vs static
                           batching tokens/sec)

The default slack factor of 2x absorbs machine-to-machine variance while
still catching the failure modes that matter: an accidental O(n) rescan
creeping back into the allocator, or the pipelined data plane silently
degrading to serial.

  python -m benchmarks.check_regression [--slack 2.0]

Exit status 1 on any gated regression. ``run_gate`` is the library entry
(tests/test_bench_smoke.py smoke-invokes it with tiny sweep configs)."""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
from typing import Dict, List, Tuple

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
if ROOT not in sys.path:  # `python benchmarks/check_regression.py` puts
    sys.path.insert(0, ROOT)  # benchmarks/ first — make the package import
COMMITTED = ("BENCH_sched.json", "BENCH_pipeline.json",
             "BENCH_preempt.json", "BENCH_serve.json")

Metric = Tuple[float, str]  # (value, "lower"|"higher" is better)


def extract_metrics(record: dict) -> Dict[str, Metric]:
    """Flatten a BENCH_*.json record into gateable {name: (value, dir)}."""
    out: Dict[str, Metric] = {}
    if record.get("bench") == "sched_scale":
        for n, cell in record.get("sizes", {}).items():
            if "indexed_us_per_op" in cell:
                out[f"sched/acquire_{n}"] = (cell["indexed_us_per_op"],
                                             "lower")
    if record.get("bench") == "pipeline_overlap":
        for cfg, cell in record.get("sweep", {}).items():
            if "speedup" in cell:
                out[f"pipeline/overlap_{cfg}"] = (cell["speedup"], "higher")
    if record.get("bench") == "preempt_frag":
        for n, cell in record.get("pools", {}).items():
            if "speedup" in cell:
                out[f"preempt/speedup_{n}"] = (cell["speedup"], "higher")
        for n, cell in record.get("defrag", {}).items():
            if "largest_run_ratio" in cell:
                out[f"defrag/largest_run_ratio_{n}"] = (
                    cell["largest_run_ratio"], "higher")
    if record.get("bench") == "serve_continuous":
        for w, cell in record.get("workloads", {}).items():
            if "speedup" in cell:
                out[f"serve/speedup_{w}"] = (cell["speedup"], "higher")
    return out


def load_committed(root: str = ROOT) -> Dict[str, Metric]:
    out: Dict[str, Metric] = {}
    for name in COMMITTED:
        path = os.path.join(root, name)
        if os.path.exists(path):
            with open(path) as f:
                out.update(extract_metrics(json.load(f)))
    return out


def compare(fresh: Dict[str, Metric], committed: Dict[str, Metric],
            slack: float = 2.0) -> List[str]:
    """Failure strings for every gated metric worse than slack x committed."""
    fails = []
    for name, (cval, direction) in sorted(committed.items()):
        if name not in fresh or cval <= 0:
            continue
        fval = fresh[name][0]
        if direction == "lower" and fval > cval * slack:
            fails.append(f"{name}: {fval:.2f} > {slack:g}x committed "
                         f"{cval:.2f}")
        elif direction == "higher" and fval < cval / slack:
            fails.append(f"{name}: {fval:.2f} < committed {cval:.2f} / "
                         f"{slack:g}")
    return fails


def run_gate(slack: float = 2.0, sched_kwargs: dict = None,
             pipe_kwargs: dict = None, preempt_kwargs: dict = None,
             serve_kwargs: dict = None, root: str = ROOT) -> List[str]:
    """Run the gated benchmarks fresh (into temp files — the committed
    records are never touched) and compare. Returns failure strings."""
    from benchmarks import (pipeline_overlap, preempt_frag, sched_scale,
                            serve_continuous)

    committed = load_committed(root)
    sched_kwargs = dict(sched_kwargs if sched_kwargs is not None else
                        # indexed rows only: the seed baseline re-run and
                        # the 100k sweep are figure material, not a gate
                        dict(sizes=(1000, 10_000), baseline_sizes=(),
                             n_jobs=100, jobs_pool=256))
    pipe_kwargs = dict(pipe_kwargs if pipe_kwargs is not None else
                       dict(stage_counts=(4,), microbatches=(1, 8)))
    preempt_kwargs = dict(preempt_kwargs if preempt_kwargs is not None else
                          # committed-record sizes — the speedup row only
                          # needs the preempt path to stay ~an order of
                          # magnitude ahead of the FIFO drain
                          dict(pool_size=10_000, attempts=3,
                               defrag_pool=1024))
    # committed-record workload: the speedup is step-count-structural, so
    # the full config reruns in seconds and gates tight
    serve_kwargs = dict(serve_kwargs if serve_kwargs is not None else {})
    fresh: Dict[str, Metric] = {}
    with tempfile.TemporaryDirectory() as td:
        for mod, kwargs, fname in (
                (sched_scale, sched_kwargs, "sched.json"),
                (pipeline_overlap, pipe_kwargs, "pipe.json"),
                (preempt_frag, preempt_kwargs, "preempt.json"),
                (serve_continuous, serve_kwargs, "serve.json")):
            path = os.path.join(td, fname)
            mod.bench(json_path=path, **kwargs)
            with open(path) as f:
                fresh.update(extract_metrics(json.load(f)))
    # a gate that gates nothing is a broken gate, not a green one: the
    # committed records must parse to gated rows, and the fresh run must
    # overlap them
    if not committed:
        return [f"no gated rows in committed records ({COMMITTED} "
                f"missing or schema drifted under {root})"]
    if not set(fresh) & set(committed):
        return ["gate extracted 0 overlapping rows: fresh run produced "
                f"{sorted(fresh) or 'nothing'}, committed records have "
                f"{sorted(committed)} — record keys drifted?"]
    return compare(fresh, committed, slack)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--slack", type=float, default=2.0,
                    help="allowed regression factor (default 2.0)")
    args = ap.parse_args(argv)
    fails = run_gate(slack=args.slack)
    if fails:
        for f in fails:
            print(f"REGRESSION {f}")
        return 1
    print(f"check_regression: all gated rows within {args.slack:g}x "
          "of committed records")
    return 0


if __name__ == "__main__":
    sys.exit(main())
