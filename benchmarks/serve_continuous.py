"""Serving plane: continuous batching vs static batching on a ragged
Zipf-length workload (DESIGN.md §10).

Both schedulers run the *same* compiled paged-decode step at the same
lane count against the same HBM page budget (what static batching would
reserve for a worst-case batch), so the measured tokens/sec difference is
pure scheduling: the static baseline drains every batch at its
straggler's speed while continuous batching refills a retiring lane on
the very next token. The speedup is structurally step-count-driven
(total decode steps taken), making the gated row stable across hosts.

``python -m benchmarks.serve_continuous`` writes BENCH_serve.json;
benchmarks/check_regression.py gates ``serve/speedup_zipf`` against the
committed record.
"""
from __future__ import annotations

import json

import numpy as np


def bench(*, n_requests: int = 64, lanes: int = 8, prompt_len: int = 8,
          max_new_cap: int = 64, zipf_a: float = 1.6, page_size: int = 8,
          repeats: int = 2, seed: int = 0, json_path: str = None):
    import jax

    from repro.serve import (ContinuousEngine, LMConfig,
                             equal_page_budget, make_zipf_requests,
                             timed_drain, warmup_engine)
    from repro.serve import model as PM

    cfg = LMConfig(page_size=page_size)
    params = PM.init(cfg, jax.random.PRNGKey(seed))
    per_seq, num_pages = equal_page_budget(lanes, prompt_len, max_new_cap,
                                           page_size)

    def engine(mode):
        return ContinuousEngine(cfg, params, lanes=lanes,
                                num_pages=num_pages,
                                max_pages_per_seq=per_seq, mode=mode)

    def workload():
        return make_zipf_requests(cfg.vocab, np.random.default_rng(seed),
                                  n_requests, prompt_len, zipf_a=zipf_a,
                                  max_new_cap=max_new_cap)

    # compile the shared step executable outside both timed regions
    warmup_engine(cfg, params, lanes=lanes, num_pages=num_pages,
                  max_pages_per_seq=per_seq)

    def best_of(mode):
        # best-of-N per scheduler: the step counts are deterministic,
        # only wall time is noisy, so the fastest run is the fair one
        runs = [timed_drain(engine(mode), workload())
                for _ in range(max(repeats, 1))]
        return max(runs, key=lambda s: s["tok_per_s"])

    cont = best_of("continuous")
    stat = best_of("static")
    assert cont["generated_tokens"] == stat["generated_tokens"], (
        "schedulers disagree on the workload's token count")
    speedup = cont["tok_per_s"] / max(stat["tok_per_s"], 1e-9)
    step_ratio = stat["steps"] / max(cont["steps"], 1)

    rows = [
        ("serve/continuous_tok_s", cont["tok_per_s"],
         f"steps={cont['steps']};preempt={cont['preemptions']}"),
        ("serve/static_tok_s", stat["tok_per_s"],
         f"steps={stat['steps']}"),
        ("serve/speedup_zipf", speedup,
         f"step_ratio={step_ratio:.2f};gen_tokens="
         f"{cont['generated_tokens']};lanes={lanes};pages={num_pages}"),
    ]
    if json_path:
        record = {
            "bench": "serve_continuous",
            "config": {"n_requests": n_requests, "lanes": lanes,
                       "prompt_len": prompt_len,
                       "max_new_cap": max_new_cap, "zipf_a": zipf_a,
                       "page_size": page_size, "num_pages": num_pages,
                       "seed": seed},
            "workloads": {"zipf": {
                "continuous_tok_s": cont["tok_per_s"],
                "static_tok_s": stat["tok_per_s"],
                "speedup": speedup,
                "step_ratio": step_ratio,
                "gen_tokens": cont["generated_tokens"],
                "steps_continuous": cont["steps"],
                "steps_static": stat["steps"],
                "preemptions": cont["preemptions"],
            }},
        }
        with open(json_path, "w") as f:
            json.dump(record, f, indent=1, sort_keys=True)
    return rows


if __name__ == "__main__":
    import os
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_serve.json")
    for r in bench(json_path=path):
        print(",".join(str(x) for x in r))
