"""Paper Fig. 5 — resource sharing: four jobs with heterogeneous slice
shapes submitted together; FIFO allocation; disjoint slices run
concurrently and the pool is fully returned at the end.

Slice configs mirror the paper: Slice1/2 = 2node-2gpu (P100), Slice3 =
1node-1gpu (P40), Slice4 = 4node-1gpu (P100)."""
from __future__ import annotations

import time

from repro.core import DevicePool, FlowOSRM, JobSpec, TaskSpec


def bench():
    # pool: 8 P100-class + 2 P40-class accelerators (virtual fleet)
    pool = DevicePool.virtual(10, devices_per_node=2,
                              kinds={(0, 8): "p100", (8, 10): "p40"})
    rm = FlowOSRM(pool)

    def job(name, n, kind, dur):
        return JobSpec(name=name, tasks=[TaskSpec(
            name="t", n_devices=n, kind=kind,
            task_fn=lambda s: time.sleep(dur))])

    t0 = time.perf_counter()
    ids = [
        rm.submit(job("slice1", 4, "p100", 0.05)),  # 2node-2gpu
        rm.submit(job("slice2", 4, "p100", 0.05)),  # 2node-2gpu
        rm.submit(job("slice3", 1, "p40", 0.03)),   # 1node-1gpu P40
        rm.submit(job("slice4", 4, "p100", 0.04)),  # 4node-1gpu
    ]
    rm.run_until_idle()
    wall = time.perf_counter() - t0

    recs = [rm.status(i) for i in ids]
    assert all(r["status"] == "done" for r in recs)
    # slices 1+2 fill the p100 pool; slice3 runs concurrently on p40;
    # slice4 waits for p100 capacity (FIFO)
    durations = {r["name"]: r["end_time"] - r["start_time"] for r in recs}
    serial = sum(durations.values())
    rows = [("sharing/4jobs_wall", wall * 1e6,
             f"speedup_vs_serial={serial / wall:.2f}")]
    for r in recs:
        rows.append((f"sharing/{r['name']}",
                     (r["end_time"] - r["submit_time"]) * 1e6,
                     f"queued={r['start_time'] - r['submit_time']:.3f}s"))
    rows.append(("sharing/final_utilization", 0.0,
                 f"util={pool.utilization():.2f}"))
    return rows


if __name__ == "__main__":
    for r in bench():
        print(",".join(str(x) for x in r))
