"""Paper §2 — device-disaggregation overhead: ExpEther host-to-device
bandwidth is ~20% of local PCIe, but compute-bound kernels are barely
affected.

CPU analogue: measure (a) the meta-accelerator inter-slice activation hop
bandwidth, (b) a compute-bound matmul whose time is insensitive to where
its inputs came from — reproducing the paper's conclusion that the penalty
is traffic-proportional, not compute-proportional."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DevicePool
from repro.core.meta_accel import MetaAccelerator, StageSpec


def bench(transfer_mb: int = 64, gemm_dim: int = 1024, iters: int = 10):
    pool = DevicePool.from_jax_devices(jax.devices()[:1],
                                       devices_per_node=1)
    meta = MetaAccelerator(pool)
    rows = []

    # (a) inter-slice transfer bandwidth (the FiC-network hop)
    stage = StageSpec(name="hop", kind=None, n_devices=1,
                      mesh_shape=(1, 1), axis_names=("data", "model"))
    slices = meta.allocate([stage])
    x = jnp.ones((16, transfer_mb << 14), jnp.float32)  # transfer_mb MB
    meta.transfer(slices[0], x, "warmup")
    before = meta.transfer_totals()
    meta.transfer(slices[0], x, "hop")
    tot = meta.transfer_totals()
    log = {"bytes": tot["bytes"] - before["bytes"],
           "seconds": tot["seconds"] - before["seconds"]}
    bw = log["bytes"] / max(log["seconds"], 1e-9)
    rows.append((f"disagg/transfer_{transfer_mb}MB", log["seconds"] * 1e6,
                 f"bandwidth_GBps={bw / 1e9:.2f}"))
    meta.release(slices)

    # (b) compute-bound op: time independent of transfer path
    a = jnp.ones((gemm_dim, gemm_dim), jnp.float32)
    f = jax.jit(lambda a: a @ a)
    f(a).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(a)
    out.block_until_ready()
    gemm_t = (time.perf_counter() - t0) / iters
    rows.append((f"disagg/gemm_{gemm_dim}", gemm_t * 1e6,
                 f"gflops={2 * gemm_dim**3 / gemm_t / 1e9:.1f}"))
    return rows


if __name__ == "__main__":
    for r in bench():
        print(",".join(str(x) for x in r))
