"""Preemption + defragmentation: time-to-placement under contention.

Two scenarios against the FlowOS-RM policy layer (DESIGN.md §9):

* **Preemption**: a 10k-device pool is ~90% filled with small long-lived
  preemptible jobs; a highest-priority large-slice job (half the pool)
  arrives. FIFO baseline: it waits until enough small jobs *finish*.
  With cooperative preemption: the RM asks just enough low-priority jobs
  to checkpoint and yield, and the big job places in bounded time —
  ``speedup = ttp_fifo / ttp_preempt`` (acceptance floor: >=10x).
* **Defragmentation**: a single-pod pool is checkerboarded (alternating
  held / freed leases) and the idle-time compaction pass relocates held
  leases until the free capacity re-coalesces —
  ``largest_run_ratio = largest_free_run_after / before``.

Both gated metrics are **capped** before they are recorded
(``speedup`` at 30x, ``largest_run_ratio`` at 16x): on a fast box the
raw ratios explode (a 5ms placement against a 1.5s baseline is 300x),
and a committed record that optimistic would make the 2x-slack
regression gate unpassable on a loaded CI runner. The caps keep the
gated floor meaningful (15x / 8x) without tracking machine luck. Raw
values are recorded alongside.

``python -m benchmarks.preempt_frag`` writes BENCH_preempt.json;
benchmarks/check_regression.py gates both rows.
"""
from __future__ import annotations

import contextlib
import json
import os
import sys
import threading
import time

from repro.core import DevicePool, FlowOSRM, JobSpec, Preempted, TaskSpec

SPEEDUP_CAP = 30.0
RATIO_CAP = 16.0


def _poll_task(stop, dur_s, poll_s):
    """Cooperative long-lived task: runs for ``dur_s`` (or until ``stop``),
    yielding via Preempted when the RM asks. Blocks on the slice's
    preempt event (wait_preempt) so hundreds of these cost no GIL churn
    and the preemption wake is immediate; ``stop`` is only checked every
    ``poll_s`` (the drain path, not the measured path)."""
    def task(s):
        deadline = time.perf_counter() + dur_s
        while not stop.is_set():
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                return
            if s.wait_preempt(min(remaining, poll_s)):
                raise Preempted()
    return task


@contextlib.contextmanager
def _fast_thread_handoff(interval_s=0.0005):
    """Thread.start() blocks until the child first runs — one GIL switch
    interval (5ms default) per job once hundreds of job threads exist.
    Dispatching a 562-job fill at 5ms/start would take ~3s of pure
    handoff; a 0.5ms interval makes the fill phase ~10x faster without
    touching the system under test."""
    prev = sys.getswitchinterval()
    sys.setswitchinterval(interval_s)
    try:
        yield
    finally:
        sys.setswitchinterval(prev)


def _gap_task(go):
    """Holds its lease until ``go`` fires — lets the driver build a
    deterministic checkerboard before any capacity returns."""
    def task(s):
        go.wait(60.0)
    return task


def _time_to_placement(pool_size, fill_frac, small_n, small_dur_s,
                       big_frac, preempt, poll_s, timeout_s=120.0):
    """Fill the pool with small preemptible jobs, then time how long a
    highest-priority large job waits for placement."""
    pool = DevicePool.virtual(pool_size)
    with FlowOSRM(pool, preempt=preempt) as rm, _fast_thread_handoff():
        stop = threading.Event()
        n_small = int(pool_size * fill_frac) // small_n
        rm.submit_many(
            JobSpec(name=f"s{i}", preemptible=True, relocatable=True,
                    tasks=[TaskSpec(name="t", n_devices=small_n,
                                    task_fn=_poll_task(stop, small_dur_s,
                                                       poll_s))])
            for i in range(n_small))
        rm.schedule_once()   # whole fleet fits: one pass dispatches all
        leased = n_small * small_n
        assert pool.free_count() == pool_size - leased, (
            "fill decayed during dispatch — small_dur_s too short for "
            "this machine's thread-start latency")
        big_id = rm.submit(JobSpec(
            name="big", priority=100,
            tasks=[TaskSpec(name="t",
                            n_devices=int(pool_size * big_frac),
                            task_fn=lambda s: None)]))
        rec = rm.wait(big_id, timeout_s=timeout_s)
        assert rec.status.value == "done", rec.status
        ttp = rec.start_time - rec.submit_time
        preempted = sum(1 for j in rm.jobs() if j["preemptions"])
        stop.set()           # drain requeued smalls immediately
        rm.run_until_idle(timeout_s=timeout_s)
        assert pool.utilization() == 0.0
    return ttp, preempted


def _defrag_recovery(pool_size, lease_n, poll_s, settle_s=5.0):
    """Checkerboard a single-pod pool, then drive defragment() to
    convergence; returns (frag_before, frag_after, largest_before,
    largest_after, moves)."""
    pool = DevicePool.virtual(pool_size, devices_per_pod=pool_size)
    with FlowOSRM(pool, relocation_limit=16) as rm, _fast_thread_handoff():
        stop, go = threading.Event(), threading.Event()
        specs = []
        for i in range(pool_size // lease_n):
            if i % 2 == 0:
                specs.append(JobSpec(
                    name=f"keep{i}", preemptible=True, relocatable=True,
                    tasks=[TaskSpec(name="t", n_devices=lease_n,
                                    task_fn=_poll_task(stop, 600.0,
                                                       poll_s))]))
            else:
                specs.append(JobSpec(
                    name=f"gap{i}",
                    tasks=[TaskSpec(name="t", n_devices=lease_n,
                                    task_fn=_gap_task(go))]))
        ids = rm.submit_many(specs)
        rm.schedule_once()
        go.set()             # gaps finish -> alternating free runs
        gap_ids = ids[1::2]
        deadline = time.perf_counter() + settle_s
        while time.perf_counter() < deadline:
            if all(rm.status(i)["status"] == "done" for i in gap_ids):
                break
            time.sleep(poll_s)
        frag_before = pool.fragmentation()
        largest_before = pool.largest_free_run()
        moves = 0
        for _ in range(64):
            m = rm.defragment(max_moves=4, frag_threshold=0.2)
            moves += m
            t_end = time.perf_counter() + settle_s
            while time.perf_counter() < t_end:   # let relocations land
                rm.schedule_once()
                if rm.quiescent():
                    break
                time.sleep(poll_s)
            if m == 0:
                break
        frag_after = pool.fragmentation()
        largest_after = pool.largest_free_run()
        stop.set()
        rm.run_until_idle(timeout_s=60.0)
        assert pool.utilization() == 0.0
    return frag_before, frag_after, largest_before, largest_after, moves


def bench(pool_size=10_000, fill_frac=0.9, small_n=32, small_dur_s=3.0,
          big_frac=0.5, poll_s=0.1, attempts=2,
          defrag_pool=1024, defrag_lease_n=8, defrag_poll_s=0.005,
          json_path=None):
    rows = []
    record = {"bench": "preempt_frag", "pools": {}, "defrag": {}}

    def ttp(preempt):
        # a transiently overloaded box can stretch the fill dispatch past
        # small_dur_s (the in-bench assert); retry rather than fail the
        # whole sweep
        last = None
        for _ in range(3):
            try:
                return _time_to_placement(pool_size, fill_frac, small_n,
                                          small_dur_s, big_frac,
                                          preempt=preempt, poll_s=poll_s)
            except AssertionError as e:
                last = e
        raise last

    ttp_fifo, _ = ttp(preempt=False)
    ttp_pre, preempted = min((ttp(preempt=True)
                              for _ in range(max(attempts, 1))),
                             key=lambda r: r[0])
    raw = ttp_fifo / max(ttp_pre, 1e-9)
    speedup = min(raw, SPEEDUP_CAP)
    rows.append((f"preempt_frag/ttp_fifo_{pool_size}",
                 f"{ttp_fifo * 1e6:.2f}", "large_job_waits_for_drain"))
    rows.append((f"preempt_frag/ttp_preempt_{pool_size}",
                 f"{ttp_pre * 1e6:.2f}",
                 f"speedup={raw:.1f}x_preempted={preempted}"))
    record["pools"][str(pool_size)] = {
        "ttp_fifo_s": ttp_fifo, "ttp_preempt_s": ttp_pre,
        "speedup": speedup, "speedup_raw": raw, "preempted": preempted}

    fb, fa, lb, la, moves = _defrag_recovery(defrag_pool, defrag_lease_n,
                                             defrag_poll_s)
    raw_ratio = la / max(lb, 1)
    ratio = min(raw_ratio, RATIO_CAP)
    rows.append((f"preempt_frag/defrag_{defrag_pool}",
                 f"{moves:.0f}",
                 f"largest_{lb}->{la}_frag_{fb:.2f}->{fa:.2f}"))
    record["defrag"][str(defrag_pool)] = {
        "frag_before": fb, "frag_after": fa,
        "largest_before": lb, "largest_after": la,
        "largest_run_ratio": ratio, "largest_run_ratio_raw": raw_ratio,
        "moves": moves}

    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=2)
    return rows


if __name__ == "__main__":
    out = os.path.join(os.path.dirname(__file__), "..",
                       "BENCH_preempt.json")
    for r in bench(json_path=os.path.abspath(out)):
        print(",".join(str(x) for x in r))
