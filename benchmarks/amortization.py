"""Paper Fig. 4b/4c — on a long (ImageNet-scale) job the same slice
construction/destruction overhead amortizes to ~0.15-0.17% of total time.

We run short and long versions of the same job through the full lifecycle
and report the measured overhead fraction for each."""
from __future__ import annotations

from repro.launch.train import load_config, run_training


def bench(step_sets=(("short_job", 4), ("long_job", 60))):
    cfg = load_config("smollm-360m", smoke=True)
    rows = []
    for name, steps in step_sets:
        out = run_training(cfg, steps=steps, batch=4, seq=64)
        b = out["breakdown"]
        total = sum(b.values())
        frac = (total - b["run_task"]) / total
        rows.append((f"amortization/{name}", total * 1e6,
                     f"overhead_frac={frac:.4f}"))
    return rows


if __name__ == "__main__":
    for r in bench():
        print(",".join(str(x) for x in r))
