"""Paper Fig. 4a — slice lifecycle breakdown for a *short* training job on
the three slice shapes (4node-1gpu / 2node-2gpu / 1node-4gpu analogues).

The paper's finding: for an MNIST-scale job, slice construction+destruction
is 32-45% of total wall time, launch-machine grows with node count (image
staging), attach-device grows with accelerators per node (serial attach).
We reproduce the *operations* with real wall time on CPU: compile is the
launch-machine analogue, lease ops are attach/detach, plus the paper's
measured per-op costs injected as a calibrated simulation column
(sim: image staging 3GB over GbE per node; 1.2s per device attach)."""
from __future__ import annotations

import time

from repro.core import DevicePool, FlowOSRM, JobSpec, TaskSpec
from repro.launch.train import load_config, run_training

# (name, nodes, accels/node) — the paper's three slice shapes
SLICE_SHAPES = [("4node-1gpu", 4, 1), ("2node-2gpu", 2, 2),
                ("1node-4gpu", 1, 4)]

# calibrated against the paper's Fig. 4a (seconds)
SIM_IMAGE_STAGE_PER_NODE = 24.0  # 3GB over GbE
SIM_ATTACH_PER_DEVICE = 1.2
SIM_DETACH_PER_DEVICE = 0.9


def bench(steps: int = 6, shapes=None):
    cfg = load_config("smollm-360m", smoke=True)
    rows = []
    for name, nodes, per_node in (shapes if shapes is not None
                                  else SLICE_SHAPES):
        out = run_training(cfg, steps=steps, batch=4, seq=64)
        b = out["breakdown"]
        # simulated disaggregated-fabric costs on top of measured ops
        sim_construct = (SIM_IMAGE_STAGE_PER_NODE * nodes
                         + SIM_ATTACH_PER_DEVICE * nodes * per_node)
        sim_destruct = SIM_DETACH_PER_DEVICE * nodes * per_node
        measured_total = sum(b.values())
        frac = (measured_total - b["run_task"]) / measured_total
        rows.append((
            f"lifecycle/{name}/run_task", b["run_task"] * 1e6,
            f"measured_overhead_frac={frac:.3f}"))
        rows.append((
            f"lifecycle/{name}/construct+destruct_sim",
            (sim_construct + sim_destruct) * 1e6,
            f"sim_frac_short_job={(sim_construct + sim_destruct) / (sim_construct + sim_destruct + 105):.2f}"))
    return rows


if __name__ == "__main__":
    for r in bench():
        print(",".join(str(x) for x in r))
