# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper table/figure.

  lifecycle       Fig. 4a  slice lifecycle breakdown (3 slice shapes)
  amortization    Fig. 4b/c overhead amortization on long jobs
  sharing         Fig. 5   FIFO multi-job resource sharing
  disagg_overhead §2       disaggregated-fabric transfer vs compute-bound
  scaling         Fig. 4a  runtask vs slice placement (ICI vs DCN model)
  kernels         —        per-kernel interpret-mode timing vs jnp oracle
  roofline        —        roofline terms from the dry-run artifacts
  sched_scale     —        acquire latency + jobs/sec vs fleet size
  pipeline_overlap §2/§3   microbatch pipelining vs the serial data plane
  preempt_frag    §4/§9    preemption time-to-placement + defrag recovery
  serve_continuous §10     continuous vs static batching tokens/sec

``--smoke`` runs every module at tiny sizes and never touches the
committed BENCH_*.json records — the CI fast path (a full run is the
canonical refresh of the tracked records).

benchmarks/check_regression.py gates a fresh run of the tracked rows
(sched/acquire, pipeline/overlap, preempt/speedup, defrag/...) against
the committed BENCH_*.json.
"""
from __future__ import annotations

import argparse
import sys
import traceback

# tiny per-module kwargs for --smoke: exercise every bench's full code
# path in seconds (tests/test_bench_smoke.py runs the same shapes)
SMOKE_KWARGS = {
    "lifecycle": dict(steps=1, shapes=[("1node-4gpu", 1, 4)]),
    "amortization": dict(step_sets=(("short_job", 1),)),
    "disagg_overhead": dict(transfer_mb=1, gemm_dim=64, iters=2),
    "sched_scale": dict(sizes=(64,), baseline_sizes=(64,), idx_iters=20,
                        seed_iters=5, n_jobs=8, jobs_pool=32),
    "pipeline_overlap": dict(stage_counts=(2,), microbatches=(1, 2),
                             batch=8, compute_s=0.002, iters=1),
    "preempt_frag": dict(pool_size=256, fill_frac=0.75, small_n=8,
                         small_dur_s=0.4, big_frac=0.5, attempts=1,
                         defrag_pool=64, defrag_lease_n=4),
    "serve_continuous": dict(n_requests=12, lanes=4, prompt_len=4,
                             max_new_cap=16),
}


def main(argv=None) -> None:
    import os

    from benchmarks import (amortization, disagg_overhead, kernels,
                            lifecycle, pipeline_overlap, preempt_frag,
                            roofline, scaling, sched_scale,
                            serve_continuous, sharing)

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[1])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes, committed BENCH_*.json untouched")
    args = ap.parse_args(argv)

    # the full harness run is the canonical refresh of the tracked
    # records; --smoke leaves them alone
    repo_root = os.path.abspath(os.path.join(
        os.path.dirname(__file__), ".."))
    json_for = (dict.fromkeys(
        ("sched_scale", "pipeline_overlap", "preempt_frag",
         "serve_continuous")) if args.smoke
        else {"sched_scale": os.path.join(repo_root, "BENCH_sched.json"),
              "pipeline_overlap": os.path.join(repo_root,
                                               "BENCH_pipeline.json"),
              "preempt_frag": os.path.join(repo_root,
                                           "BENCH_preempt.json"),
              "serve_continuous": os.path.join(repo_root,
                                               "BENCH_serve.json")})
    named = [
        ("lifecycle", lifecycle), ("amortization", amortization),
        ("sharing", sharing), ("disagg_overhead", disagg_overhead),
        ("scaling", scaling), ("kernels", kernels),
        ("roofline", roofline), ("sched_scale", sched_scale),
        ("pipeline_overlap", pipeline_overlap),
        ("preempt_frag", preempt_frag),
        ("serve_continuous", serve_continuous),
    ]
    modules = []
    for name, mod in named:
        kwargs = dict(SMOKE_KWARGS.get(name, {})) if args.smoke else {}
        if name in json_for and json_for[name]:
            kwargs["json_path"] = json_for[name]
        modules.append((name, lambda mod=mod, kw=kwargs: mod.bench(**kw)))
    print("name,us_per_call,derived")
    failures = 0
    for name, bench_fn in modules:
        try:
            for row in bench_fn():
                print(",".join(str(x) for x in row))
            sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
