# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper table/figure.

  lifecycle       Fig. 4a  slice lifecycle breakdown (3 slice shapes)
  amortization    Fig. 4b/c overhead amortization on long jobs
  sharing         Fig. 5   FIFO multi-job resource sharing
  disagg_overhead §2       disaggregated-fabric transfer vs compute-bound
  scaling         Fig. 4a  runtask vs slice placement (ICI vs DCN model)
  kernels         —        per-kernel interpret-mode timing vs jnp oracle
  roofline        —        roofline terms from the dry-run artifacts
  sched_scale     —        acquire latency + jobs/sec vs fleet size
  pipeline_overlap §2/§3   microbatch pipelining vs the serial data plane

benchmarks/check_regression.py gates a fresh run of the tracked rows
(sched/acquire, pipeline/overlap) against the committed BENCH_*.json.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    import os

    from benchmarks import (amortization, disagg_overhead, kernels,
                            lifecycle, pipeline_overlap, roofline, scaling,
                            sched_scale, sharing)

    # the harness run is the canonical refresh of the tracked records
    repo_root = os.path.abspath(os.path.join(
        os.path.dirname(__file__), ".."))
    bench_sched_json = os.path.join(repo_root, "BENCH_sched.json")
    bench_pipeline_json = os.path.join(repo_root, "BENCH_pipeline.json")
    modules = [
        ("lifecycle", lifecycle.bench),
        ("amortization", amortization.bench),
        ("sharing", sharing.bench),
        ("disagg_overhead", disagg_overhead.bench),
        ("scaling", scaling.bench),
        ("kernels", kernels.bench),
        ("roofline", roofline.bench),
        ("sched_scale",
         lambda: sched_scale.bench(json_path=bench_sched_json)),
        ("pipeline_overlap",
         lambda: pipeline_overlap.bench(json_path=bench_pipeline_json)),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, bench_fn in modules:
        try:
            for row in bench_fn():
                print(",".join(str(x) for x in row))
            sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
