# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper table/figure.

  lifecycle       Fig. 4a  slice lifecycle breakdown (3 slice shapes)
  amortization    Fig. 4b/c overhead amortization on long jobs
  sharing         Fig. 5   FIFO multi-job resource sharing
  disagg_overhead §2       disaggregated-fabric transfer vs compute-bound
  scaling         Fig. 4a  runtask vs slice placement (ICI vs DCN model)
  kernels         —        per-kernel interpret-mode timing vs jnp oracle
  roofline        —        roofline terms from the dry-run artifacts
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (amortization, disagg_overhead, kernels,
                            lifecycle, roofline, scaling, sharing)

    modules = [
        ("lifecycle", lifecycle),
        ("amortization", amortization),
        ("sharing", sharing),
        ("disagg_overhead", disagg_overhead),
        ("scaling", scaling),
        ("kernels", kernels),
        ("roofline", roofline),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        try:
            for row in mod.bench():
                print(",".join(str(x) for x in row))
            sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
