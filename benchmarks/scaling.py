"""Paper Fig. 4a runtask rows — training time vs slice shape.

On real hardware 1node-4gpu beats 4node-1gpu because intra-node links beat
the disaggregated fabric. The TPU-pod analogue is intra-pod ICI vs
cross-pod DCN: we model runtask for the same job on (a) an ICI-contiguous
slice and (b) a pod-spanning slice using the roofline terms from the
dry-run artifacts (collective term switches from ICI to DCN bandwidth)."""
from __future__ import annotations

import json
import os

from repro.launch.analysis import DCN_BW, ICI_BW

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def _load(path):
    recs = []
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                recs.append(json.loads(line))
    return recs


def bench():
    rows = []
    singles = {(r["arch"], r["shape"]): r
               for r in _load(os.path.join(RESULTS, "dryrun_single.jsonl"))
               if r.get("status") == "ok"}
    for arch in ("qwen2.5-3b", "mamba2-370m"):
        r = singles.get((arch, "train_4k"))
        if not r:
            continue
        coll_bytes = sum(r["coll_bytes_per_dev"].values())
        contiguous = max(r["compute_s"], r["memory_s"],
                         coll_bytes / ICI_BW)
        spanning = max(r["compute_s"], r["memory_s"],
                       coll_bytes / DCN_BW)
        rows.append((f"scaling/{arch}/ici_slice",
                     contiguous * 1e6,
                     f"modeled_step_s={contiguous:.3f}"))
        rows.append((f"scaling/{arch}/dcn_spanning_slice",
                     spanning * 1e6,
                     f"slowdown={spanning / contiguous:.2f}x"))
    if not rows:
        rows.append(("scaling/no_dryrun_artifacts", 0.0,
                     "run repro.launch.dryrun first"))
    return rows


if __name__ == "__main__":
    for r in bench():
        print(",".join(str(x) for x in r))
