"""Kernel micro-bench: interpret-mode wall time is meaningless for TPU perf,
so the derived column reports the *analytic* VMEM working set and arithmetic
intensity per kernel tile — the numbers that justify the BlockSpec choices
(see DESIGN.md §8)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(f, *args, iters=3):
    # warm up (trace/compile) and sync the whole result pytree: the old
    # tuple-only sync let non-tuple outputs leak async work into the
    # timed region below
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def bench():
    rows = []

    # flash attention tile: bq=256, bkv=512, D=128 (bf16)
    bq, bkv, D = 256, 512, 128
    vmem = (bq * D + 2 * bkv * D) * 2 + bq * bkv * 4 + bq * (D + 2) * 4
    flops = 2 * bq * bkv * D * 2
    rows.append(("kernels/flash_attention_tile", 0.0,
                 f"vmem_KB={vmem // 1024};ai_flops_per_byte="
                 f"{flops / vmem:.0f}"))

    # ssd tile: chunk=128, N=128, P=64
    L, N, P = 128, 128, 64
    vmem = (L * P + 2 * L * N) * 2 + L * L * 4 + N * P * 4
    flops = 2 * L * L * N + 2 * L * L * P + 4 * L * N * P
    rows.append(("kernels/ssd_tile", 0.0,
                 f"vmem_KB={vmem // 1024};ai={flops / vmem:.0f}"))

    # moe ffn tile: bc=256, d=4096, bf=512
    bc, d, bf = 256, 4096, 512
    vmem = (bc * d + 2 * d * bf + bf * d) * 2 + bc * d * 4
    flops = 2 * bc * d * bf * 3
    rows.append(("kernels/moe_ffn_tile", 0.0,
                 f"vmem_KB={vmem // 1024};ai={flops / vmem:.0f}"))

    # interpret-mode correctness spot check timing (CPU, not perf)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64))
    k = jax.random.normal(ks[1], (1, 1, 256, 64))
    v = jax.random.normal(ks[2], (1, 1, 256, 64))
    from repro.kernels.flash_attention import flash_attention_fwd
    t = _time(lambda a, b, c: flash_attention_fwd(a, b, c, bq=128, bkv=128),
              q, k, v)
    rows.append(("kernels/flash_interpret_256", t * 1e6,
                 "correctness_mode=interpret"))
    return rows


if __name__ == "__main__":
    for r in bench():
        print(",".join(str(x) for x in r))
