"""Control-plane scale: acquire latency + scheduler throughput vs fleet size.

DxPU-scale disaggregated pools reach tens of thousands of devices; the
seed's ``_contiguous_block`` re-sorted and double-rescanned the whole free
list on every ``acquire`` (O(F log F) per op), and the seed scheduler
sleep-polled at 5ms. This benchmark measures:

  * steady-state acquire/release churn latency on the indexed pool at
    1k / 10k / 100k virtual devices,
  * the same churn through a faithful copy of the seed allocator
    (baseline — expected >=10x slower at 10k devices),
  * end-to-end FlowOS-RM jobs/sec for a 1000-job FIFO workload driven by
    condition-variable wakeups (no sleep polling).

``python -m benchmarks.sched_scale`` also writes BENCH_sched.json so the
speedup is tracked across PRs.
"""
from __future__ import annotations

import json
import os
import random
import time

from repro.core import DevicePool, FlowOSRM, JobSpec, TaskSpec
from repro.core.pool import Lease


class SeedDevicePool(DevicePool):
    """The seed allocator, preserved verbatim as the benchmark baseline:
    sort the entire free list, rescan it twice (single-pod pass, then
    cross-pod pass) on *every* acquire. Bypasses the free-run index on
    both acquire and release so only DeviceInfo state is used."""

    def acquire(self, n, kind=None, prefer_contiguous=True):
        with self._lock:
            free = self.free_devices(kind)
            if len(free) < n:
                raise RuntimeError(
                    f"need {n} {kind or 'any'} devices, {len(free)} free")
            chosen = None
            if prefer_contiguous:
                chosen = self._seed_contiguous_block(free, n)
            if chosen is None:
                chosen = free[:n]
            lease = Lease(next(self._lease_counter), chosen, kind or "any")
            for d in chosen:
                d.lease_id = lease.lease_id
            self._leases[lease.lease_id] = lease
            return lease

    @staticmethod
    def _seed_contiguous_block(free, n):
        free_sorted = sorted(free, key=lambda d: d.uid)
        for single_pod in (True, False):
            run = []
            for d in free_sorted:
                if run and (d.uid != run[-1].uid + 1
                            or (single_pod and d.pod != run[-1].pod)):
                    run = []
                run.append(d)
                if len(run) == n:
                    return run
        return None

    def release(self, lease):
        with self._lock:
            for d in lease.devices:
                if d.lease_id == lease.lease_id:
                    d.lease_id = None
            self._leases.pop(lease.lease_id, None)


def _churn_us_per_op(pool, n_devices, iters, seed=0):
    """Fill the pool to ~50%, then time steady-state release+acquire churn
    (the hot path of a saturated scheduler)."""
    rng = random.Random(seed)
    leases = []
    target = n_devices // 2
    held = 0
    while held < target:
        n = min(rng.choice([1, 2, 4, 8, 8, 16, 32]), target - held)
        leases.append(pool.acquire(n))
        held += n
    t0 = time.perf_counter()
    for _ in range(iters):
        lease = leases.pop(rng.randrange(len(leases)))
        n = lease.n
        pool.release(lease)
        leases.append(pool.acquire(n))
    dt = time.perf_counter() - t0
    for lease in leases:
        pool.release(lease)
    return dt / (2 * iters) * 1e6  # per acquire-or-release op


def _jobs_per_sec(n_devices, n_jobs, seed=0):
    """1000-job FIFO workload, event-driven wakeups end to end."""
    rng = random.Random(seed)
    pool = DevicePool.virtual(n_devices)
    rm = FlowOSRM(pool)
    specs = [JobSpec(name=f"j{i}", tasks=[TaskSpec(
        name="t", n_devices=rng.choice([1, 2, 4, 8]))])
        for i in range(n_jobs)]
    t0 = time.perf_counter()
    ids = rm.submit_many(specs)
    rm.run_until_idle(timeout_s=300.0)
    dt = time.perf_counter() - t0
    done = sum(1 for i in ids if rm.status(i)["status"] == "done")
    assert done == n_jobs, f"{done}/{n_jobs} jobs done"
    assert pool.utilization() == 0.0
    return n_jobs / dt


def bench(sizes=(1000, 10_000, 100_000), baseline_sizes=(1000, 10_000),
          idx_iters=2000, seed_iters=30, n_jobs=1000, jobs_pool=1024,
          json_path=None):
    rows = []
    record = {"bench": "sched_scale", "sizes": {}, "jobs": {}}
    for n in sizes:
        idx_us = _churn_us_per_op(DevicePool.virtual(n), n, idx_iters)
        rows.append((f"sched_scale/acquire_indexed_{n}", f"{idx_us:.2f}",
                     "free_run_index"))
        cell = {"indexed_us_per_op": idx_us}
        if n in baseline_sizes:
            seed_us = _churn_us_per_op(SeedDevicePool.virtual(n), n,
                                       seed_iters)
            speedup = seed_us / max(idx_us, 1e-9)
            rows.append((f"sched_scale/acquire_seed_{n}", f"{seed_us:.2f}",
                         f"speedup={speedup:.1f}x"))
            cell.update(seed_us_per_op=seed_us, speedup=speedup)
        record["sizes"][str(n)] = cell
    jps = _jobs_per_sec(jobs_pool, n_jobs)
    rows.append((f"sched_scale/fifo_{n_jobs}_jobs",
                 f"{1e6 / jps:.2f}", f"jobs_per_sec={jps:.0f}"))
    record["jobs"] = {"n_jobs": n_jobs, "pool": jobs_pool,
                      "jobs_per_sec": jps}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=2)
    return rows


if __name__ == "__main__":
    out = os.path.join(os.path.dirname(__file__), "..", "BENCH_sched.json")
    for r in bench(json_path=os.path.abspath(out)):
        print(",".join(str(x) for x in r))
