"""Fault tolerance at slice level: a device fails mid-training; FlowOS-RM
shrinks the slice to the largest feasible mesh, restores the checkpoint
onto the new shardings and training continues — the 1000+-node story at
CPU scale.

  PYTHONPATH=src python examples/elastic_failover.py
"""
import tempfile

import jax

from repro.core import DevicePool, ElasticController, Slice
from repro.launch.train import load_config, run_training

cfg = load_config("smollm-360m", smoke=True)
ckpt_dir = tempfile.mkdtemp(prefix="elastic_ckpt_")

# phase 1: train on an 8-device (virtual) slice, checkpointing
print("phase 1: training on the initial slice")
out1 = run_training(cfg, steps=50, batch=4, seq=32, ckpt_dir=ckpt_dir)
print(f"  loss {out1['losses'][0]:.3f} -> {out1['final_loss']:.3f}")

# phase 2: a node fails -> elastic controller decides, slice is rebuilt
pool = DevicePool.virtual(8, devices_per_node=2)
ctl = ElasticController(pool)
s = Slice(name="train", pool=pool, n_devices=8)
s.attach_device()
failed = s.lease.devices[0].uid
pool.mark_failed([failed])
decision = ctl.check(s.lease, preferred_devices=8)
print(f"\nphase 2: device {failed} failed -> decision: {decision.action} "
      f"to {decision.n_devices} devices ({decision.reason})")
new_slice = ctl.rebuild(s, decision)
print(f"  rebuilt slice: {new_slice.lease.n} healthy devices, "
      f"mesh {new_slice.mesh_shape}")

# phase 3: resume from checkpoint on the new slice shape (re-shard happens
# in CheckpointManager.restore via target shardings)
print("\nphase 3: resume from checkpoint on the rebuilt slice")
out2 = run_training(cfg, steps=60, batch=4, seq=32, ckpt_dir=ckpt_dir,
                    resume=True)
print(f"  resumed at step 50, loss {out2['losses'][0]:.3f} -> "
      f"{out2['final_loss']:.3f} (continuous with phase 1)")
assert out2["final_loss"] < out1["losses"][0]
print("\nfailover complete: no training progress lost.")
