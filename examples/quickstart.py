"""Quickstart: submit a training job to FlowOS-RM and watch the slice
lifecycle — the paper's Fig. 2 flow in ~30 lines of user code.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import DevicePool, FlowOSRM, JobSpec, TaskSpec
from repro.launch.train import load_config, run_training

# 1. the accelerator pool (here: this machine's devices; on a fleet:
#    every chip FlowOS-RM manages)
pool = DevicePool.from_jax_devices(devices_per_node=1)
print(f"pool: {pool.size} device(s), utilization {pool.utilization():.0%}")

# 2. a job = model + data + steps; the RM picks devices, builds the slice
#    (mesh), compiles, runs, and returns the lifecycle breakdown
cfg = load_config("smollm-360m", smoke=True)
out = run_training(cfg, steps=20, batch=4, seq=64, lr=1e-2)

print(f"\nfinal loss: {out['final_loss']:.4f} "
      f"({out['steps_per_s']:.2f} steps/s)")
print("slice lifecycle (paper Fig. 4 breakdown):")
for op, seconds in out["breakdown"].items():
    print(f"  {op:16s} {seconds:8.3f}s")
b = out["breakdown"]
total = sum(b.values())
print(f"construction+destruction overhead: "
      f"{(total - b['run_task']) / total:.1%} of total "
      f"(paper: 32-45% for MNIST-scale, <0.2% for ImageNet-scale)")
