"""Meta-accelerator (paper §3): one job whose stages run on *different*
accelerator kinds — whisper's encoder on an "enc" sub-slice and decoder on
a "dec" sub-slice, activations hopping over the disaggregated fabric
(transfer bytes/time logged, the FiC-network edge).

  PYTHONPATH=src python examples/meta_accelerator.py
"""
import jax
import jax.numpy as jnp

from repro.core import DevicePool
from repro.core.meta_accel import MetaAccelerator, StageSpec
from repro.launch.train import load_config
from repro.models import whisper as W
from repro.models.registry import get_model

cfg = load_config("whisper-medium", smoke=True)
model = get_model(cfg)
key = jax.random.PRNGKey(0)
params = model.init(cfg, key)

# a heterogeneous pool: encoder accelerators + decoder accelerators
# (the paper's GPU-for-conv + FPGA-for-FC meta accelerator)
jax_dev = jax.devices()[0]
pool = DevicePool.virtual(4, devices_per_node=2,
                          kinds={(0, 2): "enc", (2, 4): "dec"})
for d in pool._devices:  # bind the real device so meshes can build
    d.device = jax_dev

B = 2
frames = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model)) * 0.02
tokens = jax.random.randint(key, (B, 8), 0, cfg.vocab_size)


def encode_stage(slice_, inputs):
    return W.encode(cfg, params, inputs["frames"])


def decode_stage(slice_, enc_out):
    x = jnp.take(params["embed"]["embedding"], tokens, axis=0)
    x = x + params["pos_embed"][:tokens.shape[1]][None]

    def body(x, p):
        return W._dec_layer(cfg, x, p, enc_out), None

    x, _ = jax.lax.scan(body, x.astype(enc_out.dtype),
                        params["dec_layers"])
    from repro.models import layers as L
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return L.unembed(params["embed"], cfg, x)


meta = MetaAccelerator(pool)
stages = [
    StageSpec(name="encoder", kind="enc", n_devices=1, mesh_shape=(1, 1),
              axis_names=("data", "model"), stage_fn=encode_stage),
    StageSpec(name="decoder", kind="dec", n_devices=1, mesh_shape=(1, 1),
              axis_names=("data", "model"), stage_fn=decode_stage),
]
slices = meta.allocate(stages)
print("meta-accelerator allocated:")
for st, s in zip(stages, slices):
    kinds = {d.kind for d in s.lease.devices}
    print(f"  stage {st.name}: {s.lease.n} x {kinds}")

logits = meta.run_pipeline(stages, slices, {"frames": frames})
print(f"\npipeline output logits: {logits.shape}")
print("inter-slice hops (the disaggregated-fabric edges):")
for hop in meta.transfer_log:
    print(f"  -> {hop['stage']}: {hop['bytes'] / 1e6:.1f} MB "
          f"in {hop['seconds'] * 1e3:.1f} ms")
meta.release(slices)
print(f"pool utilization after release: {pool.utilization():.0%}")
