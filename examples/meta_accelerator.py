"""Meta-accelerator (paper §3): one job whose stages run on *different*
accelerator kinds — whisper's encoder on an "enc" sub-slice and decoder on
a "dec" sub-slice, activations hopping over the disaggregated fabric
(transfer bytes/time logged, the FiC-network edge).

The second half pipelines the same job with ``microbatches=k``
(DESIGN.md §5): decode of microbatch m overlaps the hop + encode of
microbatch m+1, hiding the disaggregation edge from the critical path.

  PYTHONPATH=src python examples/meta_accelerator.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DevicePool
from repro.core.meta_accel import LinkModel, MetaAccelerator, StageSpec
from repro.launch.train import load_config
from repro.models import whisper as W
from repro.models.registry import get_model

cfg = load_config("whisper-medium", smoke=True)
model = get_model(cfg)
key = jax.random.PRNGKey(0)
params = model.init(cfg, key)

# a heterogeneous pool: encoder accelerators + decoder accelerators
# (the paper's GPU-for-conv + FPGA-for-FC meta accelerator)
jax_dev = jax.devices()[0]
pool = DevicePool.virtual(4, devices_per_node=2,
                          kinds={(0, 2): "enc", (2, 4): "dec"})
for d in pool._devices:  # bind the real device so meshes can build
    d.device = jax_dev

B = 8
frames = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model)) * 0.02
tokens = jax.random.randint(key, (B, 8), 0, cfg.vocab_size)


# stage bodies are jitted: one compiled executable per batch shape, so
# concurrent microbatch chunks execute inside XLA (GIL released) instead
# of interleaving thousands of eager Python dispatches. params is a
# traced argument, not a closure — closing over it would bake the
# weights into every compiled shape as XLA constants.
@jax.jit
def _encode(params, inputs):
    # tokens ride along so the decoder stage sees its microbatch's rows
    return {"enc": W.encode(cfg, params, inputs["frames"]),
            "tokens": inputs["tokens"]}


@jax.jit
def _decode(params, state):
    enc_out, toks = state["enc"], state["tokens"]
    x = jnp.take(params["embed"]["embedding"], toks, axis=0)
    x = x + params["pos_embed"][:toks.shape[1]][None]

    def body(x, p):
        return W._dec_layer(cfg, x, p, enc_out), None

    x, _ = jax.lax.scan(body, x.astype(enc_out.dtype),
                        params["dec_layers"])
    from repro.models import layers as L
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return L.unembed(params["embed"], cfg, x)


def encode_stage(slice_, inputs):
    return _encode(params, inputs)


def decode_stage(slice_, state):
    return _decode(params, state)


# LinkModel emulates the ExpEther-class edge (paper §2: ~20% of local
# PCIe) so the hop has a real cost to hide even on one physical device
meta = MetaAccelerator(pool, link=LinkModel(gbytes_per_s=0.5))
stages = [
    StageSpec(name="encoder", kind="enc", n_devices=1, mesh_shape=(1, 1),
              axis_names=("data", "model"), stage_fn=encode_stage),
    StageSpec(name="decoder", kind="dec", n_devices=1, mesh_shape=(1, 1),
              axis_names=("data", "model"), stage_fn=decode_stage),
]
slices = meta.allocate(stages)
print("meta-accelerator allocated:")
for st, s in zip(stages, slices):
    kinds = {d.kind for d in s.lease.devices}
    print(f"  stage {st.name}: {s.lease.n} x {kinds}")

payload = {"frames": frames, "tokens": tokens}
K = 2
# warm both batch shapes so XLA compiles land outside the timed runs
meta.run_pipeline(stages, slices, payload)
meta.run_pipeline(stages, slices, payload, microbatches=K)

t0 = time.perf_counter()
logits = meta.run_pipeline(stages, slices, payload)
serial_s = time.perf_counter() - t0
print(f"\nserial pipeline output logits: {logits.shape} "
      f"in {serial_s * 1e3:.0f} ms")
print("inter-slice hops (the disaggregated-fabric edges):")
for hop in list(meta.transfer_log)[-2:]:
    print(f"  -> {hop['stage']}: {hop['bytes'] / 1e6:.1f} MB "
          f"in {hop['seconds'] * 1e3:.1f} ms")

# pipelined data plane: decode of microbatch m overlaps the hop + encode
# of m+1. At smoke sizes on one shared host device both times are
# dominated by fixed dispatch overhead — benchmarks/pipeline_overlap.py
# measures the actual overlap win (>=2x at 4 stages, transfer:compute
# 1:1) with per-stage fabric edges.
t0 = time.perf_counter()
logits_mb = meta.run_pipeline(stages, slices, payload, microbatches=K)
pipe_s = time.perf_counter() - t0
tot = meta.transfer_totals()
print(f"\nmicrobatches={K}: {logits_mb.shape} in {pipe_s * 1e3:.0f} ms "
      f"(serial {serial_s * 1e3:.0f} ms at smoke size; see "
      "benchmarks/pipeline_overlap.py for the overlap sweep)")
print(f"bit-exact vs serial: "
      f"{np.array_equal(np.asarray(logits), np.asarray(logits_mb))}")
print(f"transfer totals: {tot['hops']} hops, {tot['bytes'] / 1e6:.1f} MB, "
      f"{tot['seconds']:.2f}s on the fabric")
meta.release(slices)
print(f"pool utilization after release: {pool.utilization():.0%}")
