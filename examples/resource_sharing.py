"""Paper Fig. 5 — four jobs with heterogeneous slice shapes share one
disaggregated pool under FIFO scheduling.

  PYTHONPATH=src python examples/resource_sharing.py
"""
import time

from repro.core import DevicePool, FlowOSRM, JobSpec, TaskSpec

# a fleet with two accelerator kinds (the paper's P100 + P40 pools)
pool = DevicePool.virtual(10, devices_per_node=2,
                          kinds={(0, 8): "p100", (8, 10): "p40"})
rm = FlowOSRM(pool)


def job(name, n_devices, kind, seconds):
    def work(slice_):
        print(f"  [{name}] running on {n_devices} x {kind} "
              f"(nodes {sorted(slice_.lease.nodes)})")
        time.sleep(seconds)
        return name

    return JobSpec(name=name, tasks=[TaskSpec(
        name="t", n_devices=n_devices, kind=kind, task_fn=work)])


# the paper's slice configs: 2node-2gpu x2, 1node-1gpu (P40), 4node-1gpu
ids = [
    rm.submit(job("slice1", 4, "p100", 0.3)),
    rm.submit(job("slice2", 4, "p100", 0.3)),
    rm.submit(job("slice3", 1, "p40", 0.2)),
    rm.submit(job("slice4", 4, "p100", 0.25)),
]
rm.run_until_idle()

print("\ntimeline (submit -> start -> end), FIFO allocation:")
for i in ids:
    st = rm.status(i)
    print(f"  {st['name']}: queued {st['start_time'] - st['submit_time']:.2f}s, "
          f"ran {st['end_time'] - st['start_time']:.2f}s -> {st['status']}")
print(f"pool utilization after completion: {pool.utilization():.0%}")
