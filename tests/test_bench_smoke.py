"""Tier-1 smoke for benchmarks/: run every module's ``bench()`` at tiny
sizes so drift (API changes, import errors, broken row formats) is caught
by the test suite instead of at paper-figure time."""
import pytest

import benchmarks.amortization as amortization
import benchmarks.disagg_overhead as disagg_overhead
import benchmarks.kernels as kernels
import benchmarks.lifecycle as lifecycle
import benchmarks.roofline as roofline
import benchmarks.scaling as scaling
import benchmarks.sched_scale as sched_scale
import benchmarks.sharing as sharing

TINY = [
    ("lifecycle", lambda: lifecycle.bench(
        steps=1, shapes=[("1node-4gpu", 1, 4)])),
    ("amortization", lambda: amortization.bench(
        step_sets=(("short_job", 1),))),
    ("sharing", lambda: sharing.bench()),
    ("disagg_overhead", lambda: disagg_overhead.bench(
        transfer_mb=1, gemm_dim=64, iters=2)),
    ("scaling", lambda: scaling.bench()),
    ("kernels", lambda: kernels.bench()),
    ("roofline", lambda: roofline.bench()),
    ("sched_scale", lambda: sched_scale.bench(
        sizes=(64,), baseline_sizes=(64,), idx_iters=20, seed_iters=5,
        n_jobs=8, jobs_pool=32)),
]


@pytest.mark.parametrize("name,fn", TINY, ids=[t[0] for t in TINY])
def test_bench_smoke(name, fn):
    rows = fn()
    assert rows, f"{name}.bench() returned no rows"
    for row in rows:
        assert len(row) == 3, f"{name}: row {row!r} is not (name, us, derived)"
        assert isinstance(row[0], str) and row[0], row
        float(row[1])  # us_per_call column must be numeric


def test_sched_scale_speedup_floor():
    """The indexed allocator must beat the seed sort-and-rescan path by
    a wide margin even at modest fleet size (acceptance floor is 10x at
    10k devices; benchmarks/run.py measures that — here we assert a
    conservative 3x at 4096 so tier-1 stays fast and unflaky)."""
    rows = sched_scale.bench(sizes=(4096,), baseline_sizes=(4096,),
                             idx_iters=300, seed_iters=15, n_jobs=32,
                             jobs_pool=64)
    by_name = {r[0]: r for r in rows}
    idx = float(by_name["sched_scale/acquire_indexed_4096"][1])
    seed = float(by_name["sched_scale/acquire_seed_4096"][1])
    assert seed / idx >= 3.0, f"speedup {seed / idx:.1f}x < 3x"
