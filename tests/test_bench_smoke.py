"""Tier-1 smoke for benchmarks/: run every module's ``bench()`` at tiny
sizes so drift (API changes, import errors, broken row formats) is caught
by the test suite instead of at paper-figure time."""
import pytest

import benchmarks.amortization as amortization
import benchmarks.check_regression as check_regression
import benchmarks.disagg_overhead as disagg_overhead
import benchmarks.kernels as kernels
import benchmarks.lifecycle as lifecycle
import benchmarks.pipeline_overlap as pipeline_overlap
import benchmarks.preempt_frag as preempt_frag
import benchmarks.roofline as roofline
import benchmarks.run as bench_run
import benchmarks.scaling as scaling
import benchmarks.sched_scale as sched_scale
import benchmarks.serve_continuous as serve_continuous
import benchmarks.sharing as sharing

# one source of truth for the smoke shapes: benchmarks/run.py --smoke
# runs these exact kwargs in CI, and TINY below is built from them
TINY_PREEMPT = bench_run.SMOKE_KWARGS["preempt_frag"]

TINY = [
    (name, lambda m=mod, kw=bench_run.SMOKE_KWARGS.get(name, {}):
        m.bench(**dict(kw)))
    for name, mod in [
        ("lifecycle", lifecycle), ("amortization", amortization),
        ("sharing", sharing), ("disagg_overhead", disagg_overhead),
        ("scaling", scaling), ("kernels", kernels),
        ("roofline", roofline), ("sched_scale", sched_scale),
        ("pipeline_overlap", pipeline_overlap),
        ("preempt_frag", preempt_frag),
        ("serve_continuous", serve_continuous),
    ]
]


@pytest.mark.parametrize("name,fn", TINY, ids=[t[0] for t in TINY])
def test_bench_smoke(name, fn):
    rows = fn()
    assert rows, f"{name}.bench() returned no rows"
    for row in rows:
        assert len(row) == 3, f"{name}: row {row!r} is not (name, us, derived)"
        assert isinstance(row[0], str) and row[0], row
        float(row[1])  # us_per_call column must be numeric


def test_sched_scale_speedup_floor():
    """The indexed allocator must beat the seed sort-and-rescan path by
    a wide margin even at modest fleet size (acceptance floor is 10x at
    10k devices; benchmarks/run.py measures that — here we assert a
    conservative 3x at 4096 so tier-1 stays fast and unflaky)."""
    rows = sched_scale.bench(sizes=(4096,), baseline_sizes=(4096,),
                             idx_iters=300, seed_iters=15, n_jobs=32,
                             jobs_pool=64)
    by_name = {r[0]: r for r in rows}
    idx = float(by_name["sched_scale/acquire_indexed_4096"][1])
    seed = float(by_name["sched_scale/acquire_seed_4096"][1])
    assert seed / idx >= 3.0, f"speedup {seed / idx:.1f}x < 3x"


def test_pipeline_overlap_speedup_floor():
    """The pipelined data plane must beat the serial path on a 4-stage,
    transfer:compute 1:1 chain (acceptance floor is 2x at k=8;
    benchmarks/run.py measures that). Tier-1 asserts a conservative
    1.25x with up to 3 attempts, and only on a box that can time: when
    the measured serial baseline blows past its analytic model
    (4 stages x 40ms = 160ms), the host is too loaded for thread-wakeup
    timing and the attempt is discarded — a data plane that silently
    degraded to serial still fails every calm attempt."""
    compute_s = 0.02
    model_serial_s = 4 * (compute_s + compute_s)
    best, calm_attempts = 0.0, 0
    for _ in range(3):
        rows = pipeline_overlap.bench(stage_counts=(4,),
                                      microbatches=(1, 8),
                                      compute_s=compute_s, iters=2)
        by_name = {r[0]: r for r in rows}
        assert "exact=True" in by_name["pipeline/overlap_s4_k8"][2]
        serial = float(by_name["pipeline/overlap_s4_k1"][1])
        pipelined = float(by_name["pipeline/overlap_s4_k8"][1])
        if serial > 1.5 * model_serial_s * 1e6:
            continue  # loaded box: even the serial path can't hold time
        calm_attempts += 1
        best = max(best, serial / pipelined)
        if best >= 1.25:
            return
    if calm_attempts == 0:
        pytest.skip("host too loaded for overlap timing "
                    "(serial baseline >1.5x its analytic model)")
    assert best >= 1.25, f"overlap speedup {best:.2f}x < 1.25x"


def test_check_regression_compare_logic():
    """Pure gate logic: identical records pass, >slack regressions fail
    in the right direction, metrics missing from one side are skipped."""
    committed = {"sched/acquire_1000": (10.0, "lower"),
                 "pipeline/overlap_s4_k8": (4.0, "higher"),
                 "sched/acquire_100000": (70.0, "lower")}
    ok = {"sched/acquire_1000": (12.0, "lower"),
          "pipeline/overlap_s4_k8": (3.0, "higher")}
    assert check_regression.compare(ok, committed, slack=2.0) == []
    bad = {"sched/acquire_1000": (25.0, "lower"),
           "pipeline/overlap_s4_k8": (1.5, "higher")}
    fails = check_regression.compare(bad, committed, slack=2.0)
    assert len(fails) == 2
    assert any("sched/acquire_1000" in f for f in fails)
    assert any("pipeline/overlap_s4_k8" in f for f in fails)


def test_check_regression_committed_records_parse():
    """The committed BENCH_*.json files must stay extractable — the gate
    silently gating nothing would be a broken gate."""
    committed = check_regression.load_committed()
    assert any(k.startswith("sched/acquire") for k in committed)
    assert any(k.startswith("pipeline/overlap") for k in committed)
    assert any(k.startswith("preempt/speedup") for k in committed)
    assert any(k.startswith("defrag/largest_run_ratio") for k in committed)
    assert any(k.startswith("serve/speedup") for k in committed)
    for name, (value, direction) in committed.items():
        assert value > 0 and direction in ("lower", "higher"), name
    # acceptance floor: the committed preemption record must show the
    # large job placing >=10x sooner than the FIFO baseline
    for name, (value, _) in committed.items():
        if name.startswith("preempt/speedup"):
            assert value >= 10.0, f"{name} committed below 10x: {value}"
        # acceptance floor: continuous batching >= 2x static tokens/sec
        # on the committed Zipf workload at equal page budget
        if name.startswith("serve/speedup"):
            assert value >= 2.0, f"{name} committed below 2x: {value}"


def test_check_regression_gate_smoke():
    """End-to-end gate smoke at tiny sweep sizes: a fresh mini-run must
    clear the committed records at a generous slack (this exercises the
    fresh-run + extract + compare plumbing, not the perf floor)."""
    fails = check_regression.run_gate(
        slack=50.0,
        sched_kwargs=dict(sizes=(1000,), baseline_sizes=(), idx_iters=50,
                          n_jobs=8, jobs_pool=64),
        pipe_kwargs=dict(stage_counts=(4,), microbatches=(1, 8),
                         compute_s=0.005, iters=1),
        preempt_kwargs=TINY_PREEMPT,
        serve_kwargs=bench_run.SMOKE_KWARGS["serve_continuous"])
    assert fails == [], f"gate smoke failed: {fails}"


def test_check_regression_fails_loud_without_records(tmp_path):
    """Missing/unparseable committed records must fail the gate, not
    silently gate zero rows."""
    fails = check_regression.run_gate(
        slack=50.0, root=str(tmp_path),
        sched_kwargs=dict(sizes=(64,), baseline_sizes=(), idx_iters=10,
                          n_jobs=4, jobs_pool=16),
        pipe_kwargs=dict(stage_counts=(2,), microbatches=(1, 2),
                         batch=8, compute_s=0.002, iters=1),
        preempt_kwargs=TINY_PREEMPT,
        serve_kwargs=bench_run.SMOKE_KWARGS["serve_continuous"])
    assert len(fails) == 1 and "no gated rows" in fails[0]
