"""Data pipeline, optimizer, losses, checkpointing."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: skip property tests, run the rest
    from hypothesis_compat import given, settings, st

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import SyntheticLMDataset, make_data_iterator
from repro.models.registry import get_config
from repro.optim.adamw import AdamW, global_norm
from repro.optim.compression import compress_gradients, quantize_int8
from repro.optim.schedule import cosine_schedule
from repro.train.losses import chunked_ce_loss


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_data_deterministic_and_resumable():
    cfg = get_config("smollm-360m")
    ds = SyntheticLMDataset(cfg, seq_len=32, global_batch=4, seed=7)
    a = ds.batch(5)["tokens"]
    b = ds.batch(5)["tokens"]
    np.testing.assert_array_equal(a, b)  # random access, deterministic
    c = ds.batch(6)["tokens"]
    assert not np.array_equal(a, c)
    # iterator resumes exactly
    it = make_data_iterator(ds, start_step=5, stop_step=7)
    step, batch = next(it)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(batch["tokens"]), a)


def test_data_zipf_distribution():
    cfg = get_config("smollm-360m")
    ds = SyntheticLMDataset(cfg, seq_len=512, global_batch=4, seed=0)
    toks = ds.batch(0)["tokens"].ravel()
    # low token ids must be much more frequent than high ones (Zipf)
    low = np.mean(toks < 100)
    high = np.mean(toks > 10_000)
    assert low > high


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}  # d/dw w^2
        params, state, m = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_adamw_clipping():
    opt = AdamW(lr=0.0, clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    _, _, m = opt.update({"w": jnp.full(3, 100.0)}, state, params)
    assert float(m["grad_norm"]) > 100  # reported pre-clip


def test_cosine_schedule():
    sched = cosine_schedule(1.0, warmup_steps=10, total_steps=100)
    assert float(sched(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(sched(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(sched(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=1,
                max_size=16))
def test_quantize_int8_bounded_error(vals):
    x = jnp.asarray(vals, jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.max(jnp.abs(q.astype(jnp.float32) * s - x))
    assert float(err) <= float(s) * 0.51 + 1e-6  # half-ulp of the scale


def test_compression_error_feedback_unbiased():
    """Error feedback: the accumulated error stays bounded and the sum of
    decompressed grads approaches the sum of true grads."""
    key = jax.random.PRNGKey(0)
    true_sum = jnp.zeros(64)
    sent_sum = jnp.zeros(64)
    err = None
    for i in range(50):
        g = {"g": jax.random.normal(jax.random.fold_in(key, i), (64,))}
        dec, err = compress_gradients(g, err)
        true_sum = true_sum + g["g"]
        sent_sum = sent_sum + dec["g"]
    resid = float(jnp.max(jnp.abs(true_sum - sent_sum)))
    # residual equals the current error-feedback buffer -> bounded, small
    assert resid < 1.0


# ---------------------------------------------------------------------------
# chunked CE loss
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("V,chunk", [(256, 64), (250, 64), (1000, 256)])
def test_chunked_ce_matches_naive(V, chunk):
    cfg = get_config("smollm-360m").replace(vocab_size=V, d_model=32,
                                            tie_embeddings=False)
    key = jax.random.PRNGKey(0)
    d = cfg.d_model
    embed = {"embedding": jax.random.normal(key, (V, d)) * 0.1,
             "unembed": jax.random.normal(key, (d, V)) * 0.1}
    hidden = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, d))
    targets = jax.random.randint(jax.random.fold_in(key, 2), (2, 8), 0, V)
    loss = chunked_ce_loss(cfg, embed, hidden, targets, vocab_chunk=chunk)
    logits = hidden @ embed["unembed"]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               targets[..., None], axis=-1)[..., 0]
    naive = jnp.mean(lse - gold)
    assert float(jnp.abs(loss - naive)) < 1e-4
    # gradients must also match
    g1 = jax.grad(lambda h: chunked_ce_loss(cfg, embed, h, targets,
                                            vocab_chunk=chunk))(hidden)
    g2 = jax.grad(lambda h: jnp.mean(
        jax.nn.logsumexp((h @ embed["unembed"]).astype(jnp.float32), -1)
        - jnp.take_along_axis((h @ embed["unembed"]).astype(jnp.float32),
                              targets[..., None], -1)[..., 0]))(hidden)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = {"params": {"w": jnp.arange(8.0)}, "step": jnp.asarray(3)}
    mgr.save(3, state, blocking=True)
    out = mgr.restore()
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.arange(8.0))
    assert mgr.latest_step() == 3


def test_checkpoint_async_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        mgr.save(step, {"v": jnp.full(4, float(step))})
    mgr.wait()
    steps = sorted(mgr._all_steps())
    assert steps == [3, 4]  # retention
    out = mgr.restore()
    assert float(out["v"][0]) == 4.0


def test_checkpoint_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"v": jnp.ones(4)}, blocking=True)
    # a stale tmp dir from a crashed writer must not be visible
    os.makedirs(tmp_path / "step_99.tmp", exist_ok=True)
    assert mgr.latest_step() == 1


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore onto different shardings (slice shape changed)."""
    from repro.launch.mesh import single_device_mesh
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, state, blocking=True)
    mesh = single_device_mesh()
    sh = {"w": NamedSharding(mesh, P("data", None))}
    out = mgr.restore(shardings=sh)
    assert out["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.arange(16.0).reshape(4, 4))
