"""Free-run index invariants (DESIGN.md §3).

Plain-pytest property loop (no hypothesis needed): drive the pool through
randomized acquire / release / mark_failed / mark_repaired sequences and
check, after every operation, that the incrementally-maintained index is
byte-identical to a brute-force recomputation from device state — and that
best-fit placement never spans pods when a single-pod run could serve the
request.
"""
import random

import pytest

from repro.core.pool import AllocationError, DevicePool


def brute_force_runs(pool):
    """Recompute {(pod, kind): [(start, end), ...]} from device state."""
    by_bucket = {}
    for d in sorted(pool.free_devices(), key=lambda d: d.uid):
        by_bucket.setdefault((d.pod, d.kind), []).append(d.uid)
    runs = {}
    for bucket, uids in by_bucket.items():
        out = []
        start = prev = uids[0]
        for u in uids[1:]:
            if u != prev + 1:
                out.append((start, prev + 1))
                start = u
            prev = u
        out.append((start, prev + 1))
        runs[bucket] = out
    return runs


def brute_force_counts(pool):
    counts = {}
    for d in pool.free_devices():
        counts[d.kind] = counts.get(d.kind, 0) + 1
    return counts


def single_pod_run_exists(pool, n, kind):
    return any(end - start >= n
               for (pod, k), runs in brute_force_runs(pool).items()
               if kind is None or k == kind
               for start, end in runs)


def check_index(pool):
    assert pool.free_runs() == brute_force_runs(pool)
    counts = brute_force_counts(pool)
    assert pool.free_count() == sum(counts.values())
    for kind in ("tpu", "gpu", "fpga"):
        assert pool.free_count(kind) == counts.get(kind, 0)


def make_pool(rng):
    n = rng.choice([16, 32, 48, 64])
    kinds = {}
    if rng.random() < 0.5:  # heterogeneous fleet: three kind bands
        a, b = sorted(rng.sample(range(1, n), 2))
        kinds = {(0, a): "tpu", (a, b): "gpu", (b, n): "fpga"}
    return DevicePool.virtual(
        n, devices_per_node=rng.choice([2, 4]),
        devices_per_pod=rng.choice([8, 16, 256]), kinds=kinds)


# first N seeds run everywhere; the long tail is tier-1-local / nightly
# (CI runs -m "not slow")
def _seeds(n, fast=40):
    return [s if s < fast else pytest.param(s, marks=pytest.mark.slow)
            for s in range(n)]


@pytest.mark.parametrize("seed", _seeds(500))
def test_index_matches_brute_force(seed):
    rng = random.Random(seed)
    pool = make_pool(rng)
    leases = []
    check_index(pool)
    for _ in range(30):
        op = rng.choice(["acquire", "acquire", "release", "fail", "repair"])
        if op == "acquire":
            kind = rng.choice([None, "tpu", "gpu", "fpga"])
            n = rng.randint(1, max(pool.free_count(kind), 1))
            try:
                leases.append(pool.acquire(
                    n, kind=kind,
                    prefer_contiguous=rng.random() < 0.8))
            except AllocationError:
                assert pool.free_count(kind) < n
        elif op == "release" and leases:
            pool.release(leases.pop(rng.randrange(len(leases))))
        elif op == "fail":
            uids = rng.sample(range(pool.size),
                              rng.randint(1, max(pool.size // 8, 1)))
            pool.mark_failed(uids)
        elif op == "repair":
            uids = rng.sample(range(pool.size),
                              rng.randint(1, max(pool.size // 8, 1)))
            pool.mark_repaired(uids)
        check_index(pool)
    for lease in leases:  # drain: everything must merge back into runs
        pool.release(lease)
        check_index(pool)


@pytest.mark.parametrize("seed", _seeds(120))
def test_best_fit_stays_single_pod_when_possible(seed):
    """If any single-(pod, kind) run can serve the request, the chosen
    placement must not span pods."""
    rng = random.Random(10_000 + seed)
    pool = make_pool(rng)
    leases = []
    for _ in range(25):
        if leases and rng.random() < 0.4:
            pool.release(leases.pop(rng.randrange(len(leases))))
            continue
        kind = rng.choice([None, "tpu", "gpu"])
        free = pool.free_count(kind)
        if free == 0:
            continue
        n = rng.randint(1, free)
        had_single_pod_run = single_pod_run_exists(pool, n, kind)
        lease = pool.acquire(n, kind=kind)
        leases.append(lease)
        if had_single_pod_run:
            assert not lease.cross_pod, (
                f"seed={seed}: best-fit spanned pods for n={n} "
                f"kind={kind} despite a single-pod run")
            uids = sorted(d.uid for d in lease.devices)
            assert uids == list(range(uids[0], uids[0] + n)), (
                "single-pod placement must be uid-contiguous")


def test_index_after_failed_device_in_lease():
    """A device failing while leased must not re-enter the free index on
    release; repairing it afterwards must."""
    pool = DevicePool.virtual(16, devices_per_pod=8)
    lease = pool.acquire(8)
    dead = lease.devices[3].uid
    pool.mark_failed([dead])
    check_index(pool)
    pool.release(lease)
    check_index(pool)
    assert pool.free_count() == 15
    pool.mark_repaired([dead])
    check_index(pool)
    assert pool.free_count() == 16
    assert pool.free_runs() == {(0, "tpu"): [(0, 8)], (1, "tpu"): [(8, 16)]}


def test_can_allocate_many_mixed_kind_exact():
    """kind=None demand must come out of the *leftover* after named kinds,
    not double-count the same devices."""
    pool = DevicePool.virtual(4, kinds={(0, 4): "gpu"})
    assert pool.can_allocate_many({"gpu": 4})
    assert not pool.can_allocate_many({None: 4, "gpu": 4})  # 8 > 4 free
    pool2 = DevicePool.virtual(8, kinds={(0, 4): "gpu", (4, 8): "tpu"})
    assert pool2.can_allocate_many({"gpu": 4, None: 4})
    assert not pool2.can_allocate_many({"gpu": 4, None: 5})
    lease = pool2.acquire(4, kind="tpu")
    assert pool2.can_allocate_many({"gpu": 4})
    assert not pool2.can_allocate_many({"gpu": 4, None: 1})
    pool2.release(lease)
    assert pool2.can_allocate_many({"gpu": 4, None: 4})


def test_mark_failed_is_idempotent():
    pool = DevicePool.virtual(8)
    pool.mark_failed([2, 2, 3])
    pool.mark_failed([2])
    check_index(pool)
    pool.mark_repaired([2, 2])
    pool.mark_repaired([2, 3])
    check_index(pool)
    assert pool.free_count() == 8


# ---------------------------------------------------------------------------
# fragmentation metric + compaction candidates (DESIGN.md §9)
# ---------------------------------------------------------------------------

def brute_force_largest_run(pool, kind=None):
    return max((end - start
                for (pod, k), runs in brute_force_runs(pool).items()
                if kind is None or k == kind
                for start, end in runs), default=0)


@pytest.mark.parametrize("seed", _seeds(80, fast=25))
def test_fragmentation_matches_brute_force(seed):
    """fragmentation() must equal 1 - largest_run/free recomputed from
    raw device state, at every step of a random acquire/release walk."""
    rng = random.Random(20_000 + seed)
    pool = make_pool(rng)
    leases = []
    for _ in range(25):
        if leases and rng.random() < 0.45:
            pool.release(leases.pop(rng.randrange(len(leases))))
        else:
            kind = rng.choice([None, "tpu", "gpu"])
            free = pool.free_count(kind)
            if free:
                leases.append(pool.acquire(rng.randint(1, free),
                                           kind=kind))
        for kind in (None, "tpu", "gpu", "fpga"):
            largest = brute_force_largest_run(pool, kind)
            free = pool.free_count(kind)
            assert pool.largest_free_run(kind) == largest
            expect = 0.0 if free == 0 else 1.0 - largest / free
            assert pool.fragmentation(kind) == pytest.approx(expect)


@pytest.mark.parametrize("seed", _seeds(60, fast=20))
def test_compaction_candidates_are_sound(seed):
    """Every candidate must be a live single-span lease adjacent to free
    capacity, ranked by merged-run size desc — and releasing the top
    candidate must actually produce a free run of exactly that size."""
    rng = random.Random(30_000 + seed)
    pool = DevicePool.virtual(64, devices_per_pod=64)
    leases = {}
    for _ in range(40):
        if leases and rng.random() < 0.5:
            uid = rng.choice(list(leases))
            pool.release(leases.pop(uid))
        else:
            n = rng.choice([1, 2, 4])
            if pool.free_count() >= n:
                lease = pool.acquire(n)
                leases[lease.lease_id] = lease
    cands = pool.compaction_candidates()
    merged_sizes = []
    for lease_id in cands:
        assert lease_id in leases
        lease = leases[lease_id]
        uids = sorted(d.uid for d in lease.devices)
        assert uids == list(range(uids[0], uids[-1] + 1)), "multi-span"
        bucket = (lease.devices[0].pod, lease.devices[0].kind)
        merged = pool._index.merged_run_size(bucket, uids[0],
                                             uids[-1] + 1)
        assert merged > len(uids), "candidate with no free neighbour"
        merged_sizes.append(merged)
    assert merged_sizes == sorted(merged_sizes, reverse=True)
    if cands:
        top = leases.pop(cands[0])
        expect = merged_sizes[0]
        pool.release(top)
        check_index(pool)
        runs = [r for rs in pool.free_runs().values() for r in rs]
        assert any(end - start == expect for start, end in runs), (
            f"no merged run of size {expect} after releasing top "
            f"candidate; runs={runs}")


def test_compaction_candidates_kind_filter():
    pool = DevicePool.virtual(16, devices_per_pod=16,
                              kinds={(0, 8): "gpu", (8, 16): "tpu"})
    a = pool.acquire(2, kind="gpu")
    b = pool.acquire(2, kind="tpu")
    gpu_cands = pool.compaction_candidates(kind="gpu")
    assert gpu_cands == [a.lease_id]
    assert b.lease_id in pool.compaction_candidates()
    assert b.lease_id not in gpu_cands
