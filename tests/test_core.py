"""FlowOS-RM core behaviour: pool allocation, slice lifecycle, FIFO
scheduling + resource sharing (paper Fig. 5), failures, elasticity, and the
meta-accelerator."""
import threading
import time

import pytest

from repro.core import (AllocationError, DevicePool, ElasticController,
                        FlowOSRM, JobSpec, Slice, SliceState, TaskSpec)
from repro.core.elastic import largest_feasible, mesh_shape_for
from repro.core.meta_accel import MetaAccelerator, StageSpec


# ---------------------------------------------------------------------------
# pool
# ---------------------------------------------------------------------------

def test_pool_acquire_release():
    pool = DevicePool.virtual(32, devices_per_node=4)
    lease = pool.acquire(8)
    assert lease.n == 8
    assert pool.utilization() == pytest.approx(8 / 32)
    pool.release(lease)
    assert pool.utilization() == 0.0


def test_pool_contiguous_placement():
    pool = DevicePool.virtual(32, devices_per_node=4, devices_per_pod=16)
    a = pool.acquire(8)
    b = pool.acquire(8)
    uids_a = sorted(d.uid for d in a.devices)
    assert uids_a == list(range(uids_a[0], uids_a[0] + 8))
    assert not a.cross_pod and not b.cross_pod


def test_pool_exhaustion_raises():
    pool = DevicePool.virtual(8)
    pool.acquire(8)
    with pytest.raises(AllocationError):
        pool.acquire(1)


def test_pool_prefers_single_pod_but_can_span():
    pool = DevicePool.virtual(32, devices_per_pod=16)
    pool.acquire(12)  # fragments pod 0
    lease = pool.acquire(20)  # larger than any single pod's free block
    assert lease.n == 20
    assert lease.cross_pod


def test_pool_failure_tracking():
    pool = DevicePool.virtual(16)
    lease = pool.acquire(8)
    pool.mark_failed([d.uid for d in lease.devices[:2]])
    assert len(pool.failed_in_lease(lease)) == 2
    assert len(pool.free_devices()) == 8  # failed ones not free


# ---------------------------------------------------------------------------
# slice lifecycle (paper Fig. 2 / Table 1)
# ---------------------------------------------------------------------------

def test_slice_lifecycle_order_and_timing():
    pool = DevicePool.virtual(8)
    s = Slice(name="s", pool=pool, n_devices=4)
    result, breakdown = s.run_lifecycle(
        task_fn=lambda sl: (time.sleep(0.01), "done")[1])
    assert result == "done"
    assert s.state == SliceState.DESTROYED
    assert set(breakdown) == {"attach_device", "launch_machine",
                              "prepare_task", "run_task", "detach_device",
                              "destroy_machine"}
    assert breakdown["run_task"] >= 0.01
    assert 0 <= s.overhead_fraction() < 1


def test_slice_invalid_transition():
    from repro.core.slice import LifecycleError
    pool = DevicePool.virtual(8)
    s = Slice(name="s", pool=pool, n_devices=2)
    with pytest.raises(LifecycleError):
        s.launch_machine()  # must attach first


# ---------------------------------------------------------------------------
# FlowOS-RM scheduling (paper Fig. 5)
# ---------------------------------------------------------------------------

def _job(name, n, dur=0.02, kind=None):
    return JobSpec(name=name, tasks=[TaskSpec(
        name="t", n_devices=n, kind=kind,
        task_fn=lambda s: time.sleep(dur))])


def test_fifo_resource_sharing():
    """Four jobs on a 64-device pool: the first two fill it; 3 and 4 run
    after resources free (the Fig. 5 scenario)."""
    pool = DevicePool.virtual(64)
    rm = FlowOSRM(pool)
    ids = [rm.submit(_job(f"j{i}", n, 0.05))
           for i, n in enumerate([32, 32, 8, 16])]
    rm.run_until_idle()
    recs = [rm.status(i) for i in ids]
    assert all(r["status"] == "done" for r in recs)
    # j2 (8 devices) cannot start before some earlier job finished
    starts = {r["name"]: r["start_time"] for r in recs}
    ends = {r["name"]: r["end_time"] for r in recs}
    assert starts["j2"] >= min(ends["j0"], ends["j1"]) - 0.02
    assert pool.utilization() == 0.0


def test_strict_fifo_head_of_line():
    pool = DevicePool.virtual(16)
    rm = FlowOSRM(pool, backfill=False)
    rm.submit(_job("big", 16, 0.05))
    rm.submit(_job("huge", 16, 0.01))
    rm.submit(_job("small", 2, 0.01))
    rm.schedule_once()
    # strict FIFO: small must NOT start while huge blocks the head
    assert rm.status(3)["status"] == "queued"
    rm.run_until_idle()
    assert rm.status(3)["status"] == "done"


def test_backfill():
    pool = DevicePool.virtual(16)
    rm = FlowOSRM(pool, backfill=True)
    rm.submit(_job("big", 16, 0.05))
    rm.submit(_job("huge", 16, 0.05))
    rm.submit(_job("small", 0, 0.0) if False else _job("small", 2, 0.0))
    # big runs; huge blocked; backfill lets small in? No — big holds all 16.
    rm.run_until_idle()
    assert all(rm.status(i)["status"] == "done" for i in (1, 2, 3))


def test_job_failure_releases_devices():
    pool = DevicePool.virtual(8)
    rm = FlowOSRM(pool)

    def boom(s):
        raise RuntimeError("task exploded")

    spec = JobSpec(name="bad", tasks=[TaskSpec(name="t", n_devices=4,
                                               task_fn=boom)])
    rec = rm.wait(rm.submit(spec))
    assert rec.status.value == "failed"
    assert "exploded" in rec.error
    assert pool.utilization() == 0.0


def test_rest_like_dict_roundtrip():
    spec = JobSpec(name="j", tasks=[TaskSpec(name="t", n_devices=4,
                                             arch="qwen2.5-3b",
                                             shape="train_4k")])
    d = spec.to_dict()
    spec2 = JobSpec.from_dict(d)
    assert spec2.name == "j"
    assert spec2.tasks[0].arch == "qwen2.5-3b"
    pool = DevicePool.virtual(8)
    rm = FlowOSRM(pool)
    job_id = rm.submit_dict(d)
    rec = rm.wait(job_id)
    assert rec.status.value == "done"


def test_submit_many_batch():
    pool = DevicePool.virtual(32)
    rm = FlowOSRM(pool)
    ids = rm.submit_many(_job(f"j{i}", 4, 0.005) for i in range(12))
    assert ids == list(range(1, 13))
    rm.run_until_idle()
    assert all(rm.status(i)["status"] == "done" for i in ids)
    assert pool.utilization() == 0.0


def test_two_rms_share_pool_no_deadlock():
    """Two RMs over one pool, racing for the same capacity with multi-task
    jobs: the AllocationError rollback releases capacity while holding an
    RM lock, whose fan-out wakes the *other* RM — must not deadlock, and
    each RM must be woken by the other's releases (not just its own)."""
    pool = DevicePool.virtual(8)
    rms = [FlowOSRM(pool), FlowOSRM(pool)]

    def drive(rm, tag):
        specs = [JobSpec(name=f"{tag}{i}", tasks=[
            TaskSpec(name="a", n_devices=3,
                     task_fn=lambda s: time.sleep(0.001)),
            TaskSpec(name="b", n_devices=3,
                     task_fn=lambda s: time.sleep(0.001)),
        ]) for i in range(15)]
        rm.submit_many(specs)
        rm.run_until_idle(timeout_s=60)

    threads = [threading.Thread(target=drive, args=(rm, tag), daemon=True)
               for rm, tag in zip(rms, "AB")]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=90)
    assert not any(t.is_alive() for t in threads), "cross-RM deadlock"
    for rm in rms:
        assert all(r.status.value == "done" for r in rm._jobs.values())
        rm.close()
    assert pool.utilization() == 0.0
    assert pool._release_listeners == []


# ---------------------------------------------------------------------------
# elasticity
# ---------------------------------------------------------------------------

def test_largest_feasible():
    assert largest_feasible(7) == 4
    assert largest_feasible(8) == 8
    assert largest_feasible(0) == 0
    assert mesh_shape_for(8, model_parallel=4) == (2, 4)
    assert mesh_shape_for(8, model_parallel=3) == (8, 1)


def test_elastic_shrink_on_failure():
    pool = DevicePool.virtual(16, devices_per_node=2)
    ctl = ElasticController(pool)
    s = Slice(name="s", pool=pool, n_devices=8)
    s.attach_device()
    pool.mark_failed([s.lease.devices[0].uid])
    d = ctl.check(s.lease, preferred_devices=8)
    assert d.action == "shrink"
    assert d.n_devices == 4  # largest power of two <= 7
    new = ctl.rebuild(s, d)
    assert new.lease.n == 4
    assert all(dev.healthy for dev in new.lease.devices)


def test_straggler_detection_and_eviction():
    pool = DevicePool.virtual(16, devices_per_node=2)
    ctl = ElasticController(pool, straggler_factor=1.5, patience=2)
    s = Slice(name="s", pool=pool, n_devices=8)
    s.attach_device()
    nodes = sorted(s.lease.nodes)
    slow = nodes[0]
    for _ in range(4):
        ctl.record_step({n: (0.5 if n == slow else 0.1) for n in nodes})
        stragglers = ctl.stragglers()
    assert slow in stragglers
    d = ctl.check(s.lease, preferred_devices=8)
    assert d.action == "evict"
    assert slow in d.evict_nodes


def test_elastic_grow_when_pool_frees():
    pool = DevicePool.virtual(16)
    ctl = ElasticController(pool)
    s = Slice(name="s", pool=pool, n_devices=4)
    s.attach_device()
    d = ctl.check(s.lease, preferred_devices=16)
    assert d.action == "grow"
    assert d.n_devices == 16


# ---------------------------------------------------------------------------
# meta-accelerator (heterogeneous kinds)
# ---------------------------------------------------------------------------

def test_meta_accelerator_kinds():
    pool = DevicePool.virtual(16, kinds={(0, 8): "enc-accel",
                                         (8, 16): "dec-accel"})
    meta = MetaAccelerator(pool)
    stages = [
        StageSpec(name="encode", kind="enc-accel", n_devices=4,
                  stage_fn=lambda s, x: x + 1),
        StageSpec(name="decode", kind="dec-accel", n_devices=4,
                  stage_fn=lambda s, x: x * 2),
    ]
    slices = meta.allocate(stages)
    assert {d.kind for d in slices[0].lease.devices} == {"enc-accel"}
    assert {d.kind for d in slices[1].lease.devices} == {"dec-accel"}
    out = meta.run_pipeline(stages, slices, 1)
    assert out == 4  # (1+1)*2
    meta.release(slices)
    assert pool.utilization() == 0.0


def test_meta_accelerator_insufficient_kind():
    pool = DevicePool.virtual(8, kinds={(0, 8): "enc-accel"})
    meta = MetaAccelerator(pool)
    with pytest.raises(AllocationError):
        meta.allocate([StageSpec(name="x", kind="dec-accel", n_devices=2)])
