"""Per-kernel interpret-mode validation: shape/dtype sweeps + hypothesis
property tests against the pure-jnp oracles in kernels/ref.py."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: skip property tests, run the rest
    from hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.decode_attention import decode_attention_fwd
from repro.kernels.ssd_scan import ssd_scan_fwd


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,S,D,causal,window", [
    (1, 2, 1, 256, 64, True, None),
    (2, 4, 2, 512, 64, True, None),
    (2, 4, 4, 256, 128, True, None),     # MHA
    (1, 8, 2, 512, 64, True, 128),       # GQA + sliding window
    (2, 2, 1, 256, 64, False, None),     # bidirectional (encoder)
])
def test_flash_attention_sweep(B, Hq, Hkv, S, D, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(ks[0], (B, Hq, S, D), dtype)
    k = rand(ks[1], (B, Hkv, S, D), dtype)
    v = rand(ks[2], (B, Hkv, S, D), dtype)
    out = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              bq=128, bkv=128)
    exp = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol,
                               rtol=tol)


def test_flash_attention_softcap():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = rand(ks[0], (1, 2, 256, 64))
    k = rand(ks[1], (1, 2, 256, 64))
    v = rand(ks[2], (1, 2, 256, 64))
    out = flash_attention_fwd(q, k, v, causal=True, softcap=30.0,
                              bq=128, bkv=128)
    exp = ref.attention_ref(q, k, v, causal=True, softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5,
                               rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    hq_groups=st.integers(1, 4),
    hkv=st.integers(1, 2),
    nq=st.integers(1, 3),
    causal=st.booleans(),
)
def test_flash_attention_property(hq_groups, hkv, nq, causal):
    """Property: kernel == oracle for arbitrary GQA group/blocks."""
    B, D, bq = 1, 64, 128
    S = bq * nq
    Hq = hkv * hq_groups
    ks = jax.random.split(jax.random.PRNGKey(nq * 131 + hq_groups), 3)
    q = rand(ks[0], (B, Hq, S, D))
    k = rand(ks[1], (B, hkv, S, D))
    v = rand(ks[2], (B, hkv, S, D))
    out = flash_attention_fwd(q, k, v, causal=causal, bq=bq, bkv=bq)
    exp = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=3e-5,
                               rtol=3e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,bkv", [(512, 128), (1024, 512)])
def test_decode_attention_sweep(T, bkv):
    B, Hq, Hkv, D = 3, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = rand(ks[0], (B, Hq, 1, D))
    k = rand(ks[1], (B, Hkv, T, D))
    v = rand(ks[2], (B, Hkv, T, D))
    kv_len = jnp.array([T // 4, T // 2, T], jnp.int32)
    q_pos = jnp.array([T - 1], jnp.int32)
    out = decode_attention_fwd(q, k, v, kv_len, q_pos, bkv=bkv)
    exp = ref.attention_ref(q, k, v, causal=True, kv_len=kv_len,
                            q_pos=q_pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5,
                               rtol=2e-5)


def test_decode_attention_window():
    B, Hq, Hkv, T, D = 2, 2, 1, 512, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = rand(ks[0], (B, Hq, 1, D))
    k = rand(ks[1], (B, Hkv, T, D))
    v = rand(ks[2], (B, Hkv, T, D))
    kv_len = jnp.array([400, 512], jnp.int32)
    q_pos = jnp.array([399], jnp.int32)
    out = decode_attention_fwd(q, k, v, kv_len, q_pos, window=64, bkv=128)
    exp = ref.attention_ref(q, k, v, causal=True, window=64, kv_len=kv_len,
                            q_pos=q_pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("seed", range(4))
def test_decode_attention_block_boundary_ragged(seed):
    """Randomized parity at kv_len exactly on / one off a block boundary
    — the ragged edges the paged kernel must also pass (a block whose
    last row is the only valid one, and a block that is entirely dead
    but still iterated)."""
    import random
    rng = random.Random(seed)
    B, Hq, Hkv, D, bkv = 4, 4, 2, 32, 64
    T = 256
    ks = jax.random.split(jax.random.PRNGKey(100 + seed), 3)
    q = rand(ks[0], (B, Hq, 1, D))
    k = rand(ks[1], (B, Hkv, T, D))
    v = rand(ks[2], (B, Hkv, T, D))
    boundary = bkv * rng.randint(1, T // bkv)
    lens = [boundary, max(boundary - 1, 1), min(boundary + 1, T),
            rng.randint(1, T)]
    kv_len = jnp.array(lens, jnp.int32)
    q_pos = jnp.array([T - 1], jnp.int32)
    out = decode_attention_fwd(q, k, v, kv_len, q_pos, bkv=bkv)
    exp = ref.attention_ref(q, k, v, causal=True, kv_len=kv_len,
                            q_pos=q_pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_minimal_cache():
    """kv_len=1 / q_pos=0 — a cache holding only the current token, on a
    single-block grid: the softmax must normalize over exactly one
    score, so the output is that token's value row."""
    B, Hq, Hkv, T, D = 2, 2, 1, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = rand(ks[0], (B, Hq, 1, D))
    k = rand(ks[1], (B, Hkv, T, D))
    v = rand(ks[2], (B, Hkv, T, D))
    kv_len = jnp.array([1, 1], jnp.int32)
    q_pos = jnp.array([0], jnp.int32)
    out = decode_attention_fwd(q, k, v, kv_len, q_pos, bkv=128)
    exp = jnp.broadcast_to(v[:, :, 0][:, :, None], (B, Hkv, 1, D))
    exp = jnp.repeat(exp, Hq // Hkv, axis=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_window_softcap_combined():
    """Sliding window + logit softcap together, over ragged kv_len that
    straddles a block boundary — the config the paged kernel inherits."""
    B, Hq, Hkv, T, D = 3, 4, 2, 512, 64
    ks = jax.random.split(jax.random.PRNGKey(10), 3)
    q = rand(ks[0], (B, Hq, 1, D))
    k = rand(ks[1], (B, Hkv, T, D))
    v = rand(ks[2], (B, Hkv, T, D))
    kv_len = jnp.array([128, 127, 129], jnp.int32)
    q_pos = jnp.array([128], jnp.int32)
    out = decode_attention_fwd(q, k, v, kv_len, q_pos, window=48,
                               softcap=25.0, bkv=128)
    exp = ref.attention_ref(q, k, v, causal=True, window=48, softcap=25.0,
                            kv_len=kv_len, q_pos=q_pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,S,P,G,N,chunk", [
    (1, 2, 128, 32, 1, 16, 32),
    (2, 4, 256, 64, 2, 32, 64),
    (1, 4, 128, 32, 4, 16, 128),  # single chunk
])
def test_ssd_scan_sweep(B, H, S, P, G, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = rand(ks[0], (B, H, S, P), dtype)
    dt = jax.nn.softplus(rand(ks[1], (B, H, S))).astype(jnp.float32)
    A = -jnp.exp(rand(ks[2], (H,), scale=0.3))
    Bm = rand(ks[3], (B, G, S, N), dtype)
    Cm = rand(ks[4], (B, G, S, N), dtype)
    y, state = ssd_scan_fwd(x, dt, A, Bm, Cm, chunk=chunk)
    ye, se = ref.ssd_ref(x, dt, A, Bm, Cm)
    tol = 5e-2 if dtype == jnp.bfloat16 else 5e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ye, np.float32), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(np.asarray(state), np.asarray(se),
                               atol=tol, rtol=tol)


@settings(max_examples=8, deadline=None)
@given(nc=st.integers(1, 4), h=st.integers(1, 3))
def test_ssd_state_consistency(nc, h):
    """Property: chunked final state == sequential final state."""
    B, P, N, chunk = 1, 16, 8, 16
    S = chunk * nc
    ks = jax.random.split(jax.random.PRNGKey(nc * 7 + h), 5)
    x = rand(ks[0], (B, h, S, P))
    dt = jax.nn.softplus(rand(ks[1], (B, h, S)))
    A = -jnp.exp(rand(ks[2], (h,), scale=0.3))
    Bm = rand(ks[3], (B, 1, S, N))
    Cm = rand(ks[4], (B, 1, S, N))
    _, state = ssd_scan_fwd(x, dt, A, Bm, Cm, chunk=chunk)
    _, se = ref.ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(state), np.asarray(se),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# rmsnorm / moe ffn
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("R,D", [(8, 64), (256, 96), (1000, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(R, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(5), 2)
    x = rand(ks[0], (R, D), dtype)
    w = rand(ks[1], (D,))
    out = ops.rmsnorm(x, w)
    exp = ref.rmsnorm_ref(x, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("E,C,d,f", [(2, 32, 64, 128), (4, 64, 96, 160)])
def test_moe_ffn_sweep(E, C, d, f):
    ks = jax.random.split(jax.random.PRNGKey(6), 4)
    x = rand(ks[0], (E, C, d))
    wg = rand(ks[1], (E, d, f), scale=0.1)
    wu = rand(ks[2], (E, d, f), scale=0.1)
    wd = rand(ks[3], (E, f, d), scale=0.1)
    out = ops.moe_ffn(x, wg, wu, wd)
    exp = ref.moe_ffn_ref(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-4,
                               rtol=1e-4)


def test_flash_mha_jnp_twin():
    """The pure-jnp flash (used by the dry-run) matches the kernel oracle."""
    from repro.models.layers import flash_mha
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    B, S, Hq, Hkv, D = 2, 1024, 4, 2, 64
    q = rand(ks[0], (B, S, Hq, D))
    k = rand(ks[1], (B, S, Hkv, D))
    v = rand(ks[2], (B, S, Hkv, D))
    out = flash_mha(q, k, v, causal=True)
    exp = ref.attention_ref(q.transpose(0, 2, 1, 3),
                            k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3),
                            causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5,
                               rtol=2e-5)
