"""Pipelined meta-accelerator data plane (DESIGN.md §5): bit-exact
microbatching vs. the serial path, exact per-hop transfer accounting,
bounded thread-safe transfer log, error propagation, and lifecycle
teardown on release."""
import threading

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import DevicePool
from repro.core.meta_accel import (LinkModel, MetaAccelerator, StageSpec,
                                   concat_microbatches, split_microbatches)
from repro.core.slice import SliceState


def _real_pool(n, kinds=None):
    """Virtual fleet bound to the local jax device so meshes build."""
    pool = DevicePool.virtual(n, devices_per_node=1, kinds=kinds)
    dev = jax.devices()[0]
    for d in pool._devices:
        d.device = dev
    return pool


def _stages(fns):
    return [StageSpec(name=f"s{i}", kind=None, n_devices=1,
                      mesh_shape=(1, 1), axis_names=("data", "model"),
                      stage_fn=fn) for i, fn in enumerate(fns)]


def _payload(batch):
    rng = np.random.default_rng(0)
    return {"a": rng.standard_normal((batch, 4)).astype(np.float32),
            "b": rng.standard_normal((batch, 3)).astype(np.float32),
            "gain": 3.0}  # non-array leaf: replicated into every chunk


# batch-row-independent stages over a pytree payload (elementwise ops,
# within-row reductions, concat) — bit-exact under any batch split
_FNS = [
    lambda s, x: {"a": x["a"] * x["gain"], "b": x["b"] + 1.0},
    lambda s, x: {"a": x["a"] + x["b"].sum(axis=1, keepdims=True),
                  "b": x["b"]},
    lambda s, x: jnp.concatenate([x["a"], x["b"]], axis=1),
]


def _run(meta, stages, slices, inputs, k):
    return meta.run_pipeline(stages, slices, inputs, microbatches=k)


@pytest.mark.parametrize("k", [1, 2, 8])
def test_pipelined_bit_exact_vs_serial(k):
    """Pytree payload, uneven batch (12 does not divide 8): the
    concatenated microbatch output must equal the serial path bit for
    bit."""
    pool = _real_pool(3)
    meta = MetaAccelerator(pool)
    stages = _stages(_FNS)
    slices = meta.allocate(stages)
    try:
        x = _payload(batch=12)
        ref = _run(meta, stages, slices, x, 1)
        out = _run(meta, stages, slices, x, k)
        assert np.array_equal(np.asarray(ref), np.asarray(out))
    finally:
        meta.release(slices)


def test_pipelined_with_link_model_bit_exact():
    pool = _real_pool(3)
    meta = MetaAccelerator(pool, link=LinkModel(gbytes_per_s=1.0,
                                                latency_s=1e-4))
    stages = _stages(_FNS)
    slices = meta.allocate(stages)
    try:
        x = _payload(batch=7)  # uneven for k=2 as well
        ref = _run(meta, stages, slices, x, 1)
        out = _run(meta, stages, slices, x, 2)
        assert np.array_equal(np.asarray(ref), np.asarray(out))
    finally:
        meta.release(slices)


@pytest.mark.parametrize("k", [1, 2, 4])
def test_transfer_accounting_exact(k):
    """Logged bytes must equal sum(leaf.nbytes) x hops regardless of the
    microbatch split (uneven chunks for k=4 over batch 10), and the hop
    count must be stages x k."""
    pool = _real_pool(3)
    meta = MetaAccelerator(pool)
    stages = _stages([None, None, None])  # passthrough: payload unchanged
    slices = meta.allocate(stages)
    try:
        x = {"a": np.ones((10, 4), np.float32),
             "b": np.ones((10, 2), np.int32)}
        leaf_bytes = 10 * 4 * 4 + 10 * 2 * 4
        before = meta.transfer_totals()
        _run(meta, stages, slices, x, k)
        after = meta.transfer_totals()
        assert after["bytes"] - before["bytes"] == leaf_bytes * len(stages)
        assert after["hops"] - before["hops"] == len(stages) * k
        assert after["seconds"] > before["seconds"]
    finally:
        meta.release(slices)


def test_transfer_log_bounded_totals_survive():
    """The deque evicts old hops; transfer_totals() stays exact."""
    pool = _real_pool(2)
    meta = MetaAccelerator(pool, transfer_log_maxlen=4)
    stages = _stages([None, None])
    slices = meta.allocate(stages)
    try:
        x = {"a": np.ones((8, 2), np.float32)}
        _run(meta, stages, slices, x, 8)  # 16 hops through a 4-entry log
        assert len(meta.transfer_log) == 4
        tot = meta.transfer_totals()
        assert tot["hops"] == 16
        assert tot["bytes"] == 8 * 2 * 4 * 2  # full payload x 2 stages
    finally:
        meta.release(slices)


def test_transfer_log_thread_safe():
    """Concurrent public-API hops from many threads: no lost updates."""
    pool = _real_pool(1)
    meta = MetaAccelerator(pool, transfer_log_maxlen=64)
    stages = _stages([None])
    slices = meta.allocate(stages)
    try:
        x = np.ones((4, 4), np.float32)

        def hammer():
            for _ in range(25):
                meta.transfer(slices[0], x, "t")

        threads = [threading.Thread(target=hammer) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        tot = meta.transfer_totals()
        assert tot["hops"] == 100
        assert tot["bytes"] == 100 * 4 * 4 * 4
    finally:
        meta.release(slices)


def test_link_concurrent_streams_share_bandwidth():
    """Two overlapping transfer_async calls on one fabric edge must each
    see ~half the modeled bandwidth (fluid fair share), not each be
    timed as if alone on the wire. Modeled delays are floors served by
    sleeps, so a loaded host can only make times longer — the shared-
    bandwidth lower bound cannot flake false-positive."""
    import time
    pool = _real_pool(2)
    # 4 MB at 0.05 GB/s: ~84 ms modeled single-stream wire time
    meta = MetaAccelerator(pool, link=LinkModel(gbytes_per_s=0.05))
    stages = _stages([None])
    slices = meta.allocate(stages)
    x = np.ones((1024, 1024), np.float32)
    single_model = meta.link.delay_s(x.nbytes)
    try:
        t0 = time.perf_counter()
        meta.transfer(slices[0], x, "solo")
        solo = time.perf_counter() - t0
        assert solo >= 0.95 * single_model, "solo hop undershot the model"

        # register both streams from this thread (transfer_async starts
        # occupying the edge at issue), so overlap is guaranteed no
        # matter how the completion threads get scheduled
        t0 = time.perf_counter()
        _, c1 = meta.transfer_async(slices[0], x, "pair")
        _, c2 = meta.transfer_async(slices[0], x, "pair")
        threads = [threading.Thread(target=c) for c in (c1, c2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        both = time.perf_counter() - t0
        # two fully-overlapped streams drain in 2x the single-stream
        # model (each at bandwidth/2)
        assert both >= 1.8 * single_model, (
            f"overlapped pair finished in {both:.3f}s vs single-stream "
            f"model {single_model:.3f}s: bandwidth was not shared")
        tot = meta.transfer_totals()
        assert tot["hops"] == 3 and tot["bytes"] == 3 * x.nbytes
    finally:
        meta.release(slices)


def test_link_serialized_streams_keep_full_bandwidth():
    """Back-to-back (non-overlapping) hops on the same edge must each
    still pay only the single-stream wire time — sharing applies to
    in-flight streams only. Asserted on the edge's stream state (a
    drained stream must leave the fluid model) rather than a wall-clock
    upper bound, which would flake on a stalled CI host; release() must
    then drop the edge entirely so a recycled Slice id can't inherit
    stream state."""
    pool = _real_pool(2)
    meta = MetaAccelerator(pool, link=LinkModel(gbytes_per_s=0.2))
    stages = _stages([None])
    slices = meta.allocate(stages)
    x = np.ones((512, 1024), np.float32)
    try:
        for _ in range(2):
            meta.transfer(slices[0], x, "serial")
            edge = meta._edges[id(slices[0])]
            assert edge.streams == {}, (
                "a completed hop left its stream in the fluid model — "
                "the next hop would wrongly run at bw/2")
    finally:
        meta.release(slices)
    assert meta._edges == {}, "release() must drop per-slice edges"


def test_release_runs_lifecycle_teardown():
    """Slices must end DESTROYED (not a dead ATTACHED/LAUNCHED husk),
    with the teardown transitions timed."""
    pool = DevicePool.virtual(8)
    meta = MetaAccelerator(pool)
    slices = meta.allocate([StageSpec(name="a", kind=None, n_devices=2),
                            StageSpec(name="b", kind=None, n_devices=2)])
    assert all(s.state == SliceState.LAUNCHED for s in slices)
    meta.release(slices)
    assert all(s.state == SliceState.DESTROYED for s in slices)
    assert all(s.lease is None and s.mesh is None for s in slices)
    assert all("detach_device" in s.timings
               and "destroy_machine" in s.timings for s in slices)
    assert pool.utilization() == 0.0
    meta.release(slices)  # idempotent


def test_teardown_refuses_running_slice():
    """Silently skipping a RUNNING slice would leak its lease — teardown
    must raise instead (stopping live tasks is elasticity's job)."""
    from repro.core.slice import LifecycleError, Slice
    pool = DevicePool.virtual(4)
    s = Slice(name="s", pool=pool, n_devices=2)
    s.attach_device()
    s.state = SliceState.RUNNING  # mid-task, as another thread sees it
    with pytest.raises(LifecycleError, match="running"):
        s.teardown()


def test_allocate_rollback_tears_down():
    """A mid-allocate failure must return every already-attached stage's
    devices through the lifecycle, not leave them leased."""
    pool = DevicePool.virtual(4)
    meta = MetaAccelerator(pool)
    from repro.core import AllocationError
    with pytest.raises(AllocationError):
        meta.allocate([StageSpec(name="ok", kind=None, n_devices=2),
                       StageSpec(name="toobig", kind=None, n_devices=8)])
    assert pool.utilization() == 0.0


def test_allocate_rollback_on_launch_failure():
    """A stage that attaches but fails launch_machine (bad mesh shape)
    must release its own lease too, not just the earlier stages'."""
    pool = _real_pool(4)
    meta = MetaAccelerator(pool)
    with pytest.raises(ValueError):
        # 2 devices cannot reshape into a (1, 1) mesh
        meta.allocate([StageSpec(name="ok", kind=None, n_devices=1,
                                 mesh_shape=(1, 1),
                                 axis_names=("data", "model")),
                       StageSpec(name="badmesh", kind=None, n_devices=2,
                                 mesh_shape=(1, 1),
                                 axis_names=("data", "model"))])
    assert pool.utilization() == 0.0


def test_pipelined_stage_error_propagates():
    pool = _real_pool(2)
    meta = MetaAccelerator(pool)

    def boom(s, x):
        raise RuntimeError("stage exploded")

    stages = _stages([lambda s, x: x + 1.0, boom])
    slices = meta.allocate(stages)
    try:
        with pytest.raises(RuntimeError, match="stage exploded"):
            _run(meta, stages, slices, np.ones((8, 2), np.float32), 4)
    finally:
        meta.release(slices)


def test_microbatch_validation():
    with pytest.raises(ValueError, match="batch axis"):
        split_microbatches(1, 2)  # no array leaves
    with pytest.raises(ValueError, match="not in"):
        split_microbatches(np.ones((3, 2)), 4)  # k > batch
    with pytest.raises(ValueError, match="batch axis"):
        split_microbatches({"a": np.ones((4, 2)),
                            "b": np.ones((5, 2))}, 2)  # disagreeing dim 0
    chunks = split_microbatches(np.arange(10), 4)
    assert [c.shape[0] for c in chunks] == [3, 3, 2, 2]
    assert np.array_equal(np.asarray(concat_microbatches(chunks)),
                          np.arange(10))


def test_serial_path_backward_compatible():
    """mesh-less virtual slices + scalar payload: the k=1 path must keep
    the seed semantics (transfer is a no-op, stages chain)."""
    pool = DevicePool.virtual(4)
    meta = MetaAccelerator(pool)
    stages = [StageSpec(name="inc", kind=None, n_devices=2,
                        stage_fn=lambda s, x: x + 1),
              StageSpec(name="dbl", kind=None, n_devices=2,
                        stage_fn=lambda s, x: x * 2)]
    slices = meta.allocate(stages)
    assert meta.run_pipeline(stages, slices, 1) == 4
    assert meta._transfer_to(slices[0], 1, "legacy") == 1  # old private API
    meta.release(slices)
