"""End-to-end behaviour: training jobs through FlowOS-RM slices (the
paper's MNIST/Fig-4 scenario, scaled to CPU), checkpoint/restart recovery,
and serving."""
import numpy as np
import pytest

import jax

from repro.core import DevicePool, FlowOSRM, JobSpec, TaskSpec
from repro.launch.train import run_training, load_config
from repro.launch.serve import run_serving


def test_train_job_runs_and_loss_decreases():
    cfg = load_config("smollm-360m", smoke=True)
    out = run_training(cfg, steps=8, batch=4, seq=32, lr=1e-2)
    losses = out["losses"]
    assert len(losses) == 8
    assert losses[-1] < losses[0]
    b = out["breakdown"]
    assert b["run_task"] > 0
    # the six paper operations all appear
    assert set(b) == {"attach_device", "launch_machine", "prepare_task",
                      "run_task", "detach_device", "destroy_machine"}


def test_checkpoint_restart_resumes_stream(tmp_path):
    """Kill-and-restart: second run resumes from the checkpoint and the
    data stream continues at the right step."""
    cfg = load_config("smollm-360m", smoke=True)
    d = str(tmp_path / "ckpt")
    out1 = run_training(cfg, steps=50, batch=2, seq=16, ckpt_dir=d)
    out2 = run_training(cfg, steps=60, batch=2, seq=16, ckpt_dir=d,
                        resume=True)
    # resumed run trains only steps 50..59
    assert len(out2["losses"]) == 10
    assert out2["final_loss"] < out1["losses"][0]


def test_serving_generates_tokens():
    cfg = load_config("qwen2.5-3b", smoke=True)
    out = run_serving(cfg, batch=2, prompt_len=8, decode_len=4)
    assert out["tokens"].shape == (2, 4)
    assert out["decode_tok_per_s"] > 0


def test_concurrent_jobs_share_pool():
    """Two tiny training jobs on disjoint virtual slices + real compute on
    the shared CPU device (paper Fig. 5 at CPU scale)."""
    import jax.numpy as jnp

    pool = DevicePool.virtual(8, devices_per_node=2)
    rm = FlowOSRM(pool)

    def make_task():
        def task(s):
            x = jnp.ones((64, 64))
            for _ in range(3):
                x = jnp.tanh(x @ x)
            return float(x.sum())
        return task

    ids = [rm.submit(JobSpec(name=f"j{i}", tasks=[TaskSpec(
        name="t", n_devices=4, task_fn=make_task())])) for i in range(3)]
    rm.run_until_idle()
    assert all(rm.status(i)["status"] == "done" for i in ids)
    # event log contains the full lifecycle of each job
    names = {e[1] for e in rm.events}
    assert names == {"j0", "j1", "j2"}
