"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step on CPU, asserting output shapes and no NaNs; plus
decode-vs-forward consistency for every cached family."""
import importlib

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.registry import get_model
from repro.models.config import ShapeConfig

ARCH_MODULES = [
    "qwen2_5_3b", "gemma3_1b", "minitron_8b", "smollm_360m",
    "whisper_medium", "qwen2_vl_7b", "mamba2_370m",
    "qwen3_moe_235b_a22b", "granite_moe_1b_a400m", "zamba2_2_7b",
]


def smoke_cfg(mod_name):
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.smoke()


def make_batch(cfg, key, B=2, S=16):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model)) * 0.02
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            key, (B, cfg.n_vision_patches, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("mod_name", ARCH_MODULES)
def test_forward_shapes_no_nan(mod_name):
    cfg = smoke_cfg(mod_name)
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(cfg, key)
    B, S = 2, 16
    logits, aux = model.apply(cfg, params, make_batch(cfg, key, B, S))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("mod_name", ARCH_MODULES)
def test_train_step_decreases_loss(mod_name):
    from repro.optim.adamw import AdamW
    from repro.parallel.policy import sharding_policy
    from repro.launch.mesh import single_device_mesh
    from repro.train import steps as S

    cfg = smoke_cfg(mod_name)
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    mesh = single_device_mesh()
    shape = ShapeConfig("t", 16, 2, "train")
    rules = sharding_policy(cfg, shape, mesh)
    optimizer = AdamW(lr=1e-2)
    step = jax.jit(S.make_train_step(model, optimizer, rules),
                   donate_argnums=(0,))
    params = model.init(cfg, key)
    state = S.TrainState(params, optimizer.init(params))
    batch = make_batch(cfg, key)
    with mesh:
        losses = []
        for _ in range(5):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
    assert all(np.isfinite(x) for x in losses)
    assert losses[-1] < losses[0]  # same batch -> must overfit


@pytest.mark.parametrize("mod_name", ARCH_MODULES)
def test_decode_matches_forward(mod_name):
    cfg = smoke_cfg(mod_name).replace(dtype="float32")
    model = get_model(cfg)
    if model.decode_step is None:
        pytest.skip("no decode path")
    key = jax.random.PRNGKey(0)
    params = model.init(cfg, key)
    B, S = 2, 16
    batch = make_batch(cfg, key, B, S)
    full_logits, _ = model.apply(cfg, params, batch)
    cache = model.init_cache(cfg, B, S, dtype=jnp.float32)
    if cfg.family == "audio":
        from repro.models import whisper as W
        cache["cross"] = W.prefill_cross(cfg, params, batch["frames"])
    outs = []
    toks = batch["tokens"]
    for t in range(S):
        lg, cache = model.decode_step(cfg, params, cache, toks[:, t:t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    if cfg.family == "vlm":
        # decode path has no vision embeds; compare text-only forward
        full_logits, _ = model.apply(cfg, params,
                                     {"tokens": batch["tokens"]})
    err = float(jnp.max(jnp.abs(dec - full_logits)))
    scale = float(jnp.max(jnp.abs(full_logits))) + 1e-9
    assert err / scale < 5e-4, f"decode drift {err} (rel {err/scale})"


def test_gemma3_pattern():
    from repro.models.transformer import _layer_pattern
    cfg = smoke_cfg("gemma3_1b")  # global_every=2, 4 layers
    pat = _layer_pattern(cfg)
    assert pat == [cfg.sliding_window, None, cfg.sliding_window, None]


def test_param_axes_match_params():
    """Every param leaf must have a matching logical-axes tuple."""
    for mod_name in ARCH_MODULES:
        cfg = smoke_cfg(mod_name)
        model = get_model(cfg)
        params = jax.eval_shape(lambda: model.init(cfg, jax.random.PRNGKey(0)))
        axes = model.param_axes(cfg)
        p_leaves, p_tree = jax.tree.flatten(params)
        a_leaves = p_tree.flatten_up_to(axes)
        assert len(p_leaves) == len(a_leaves)
        for p, a in zip(p_leaves, a_leaves):
            assert isinstance(a, tuple) and len(a) == p.ndim, (
                f"{mod_name}: axes {a} vs shape {p.shape}")
