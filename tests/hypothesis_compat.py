"""Fallback shim for the optional `hypothesis` dependency.

When hypothesis is installed the test modules import it directly; when it
is missing they fall back to this shim, so the *property* tests skip
cleanly while every plain test in the same module still runs (the seed
hard-imported hypothesis and the whole module failed collection).
"""
import pytest


class _AnyStrategy:
    """Stands in for any `strategies.*` expression built at decoration
    time (`st.integers(1, 4)`, `st.lists(st.floats(...))`, ...)."""

    def __call__(self, *args, **kwargs):
        return self

    def __getattr__(self, name):
        return self


st = _AnyStrategy()


def given(*args, **kwargs):
    """Replace the test body with a zero-arg skipper (a wrapper keeping the
    original signature would make pytest hunt for fixtures named after the
    hypothesis parameters)."""
    def deco(fn):
        def skipper():
            pytest.skip("hypothesis not installed (property test)")
        skipper.__name__ = fn.__name__
        skipper.__doc__ = fn.__doc__
        return skipper
    return deco


def settings(*args, **kwargs):
    return lambda fn: fn
