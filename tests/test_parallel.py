"""Sharding policy + logical-axis system: unit + hypothesis property tests
on the invariants the dry-run depends on."""
import numpy as np
import pytest

import jax
from jax.sharding import Mesh, PartitionSpec as P

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: skip property tests, run the rest
    from hypothesis_compat import given, settings, st

from repro.models.config import SHAPES
from repro.models.registry import get_config, list_architectures
from repro.parallel.policy import sharding_policy
from repro.parallel.sharding import (AxisRules, sanitize_spec)


def fake_mesh(shape=(4, 4), axes=("data", "model")):
    devs = np.array(jax.devices() * int(np.prod(shape)))[: int(np.prod(shape))]
    return Mesh(devs.reshape(shape), axes)


def test_axis_rules_dedup():
    """A mesh axis may appear at most once per spec; later uses degrade to
    replication."""
    r = AxisRules({"a": "data", "b": "data", "c": "model"})
    spec = r.spec(("a", "b", "c"))
    assert spec == P("data", None, "model")


def test_axis_rules_tuple_axes():
    r = AxisRules({"batch": ("pod", "data")})
    assert r.spec(("batch", None)) == P(("pod", "data"))


def test_sanitize_uneven():
    mesh = fake_mesh()
    # 51865 not divisible by 4 -> vocab axis dropped
    spec = sanitize_spec(mesh, P("model", "data"), (51865, 1024))
    assert spec == P(None, "data")
    # tuple axes partially dropped
    spec = sanitize_spec(mesh, P(("data", "model"),), (8,))
    assert spec == P("data")


@settings(max_examples=30, deadline=None)
@given(
    dim=st.integers(1, 10_000),
    use_tuple=st.booleans(),
)
def test_sanitize_always_divides(dim, use_tuple):
    """Property: after sanitize, every sharded dim divides evenly."""
    mesh = fake_mesh()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    entry = ("data", "model") if use_tuple else "data"
    spec = sanitize_spec(mesh, P(entry), (dim,))
    prod = 1
    for e in spec:
        if e is None:
            continue
        for name in ((e,) if isinstance(e, str) else e):
            prod *= sizes[name]
    assert dim % prod == 0


ALL_CELLS = [(a, s) for a in list_architectures() for s in SHAPES]


@pytest.mark.parametrize("arch,shape_name", ALL_CELLS)
def test_policy_covers_every_cell(arch, shape_name):
    """The policy must produce rules for every assigned cell without
    raising, and batch sharding must divide the global batch."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = fake_mesh((4, 4))
    rules = sharding_policy(cfg, shape, mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    b = rules.physical("batch")
    if b is not None:
        names = (b,) if isinstance(b, str) else b
        prod = 1
        for n in names:
            prod *= sizes[n]
        assert shape.global_batch % prod == 0
    # experts never sharded for non-moe
    if not cfg.is_moe:
        assert rules.physical("experts") in (None, "model")


def test_policy_strategies():
    mesh = fake_mesh((4, 4))
    # dense divisible batch -> pure_dp
    cfg = get_config("qwen2.5-3b")
    r = sharding_policy(cfg, SHAPES["train_4k"], mesh)
    assert r.strategy == "pure_dp"
    # moe -> dp_ep with experts on model
    cfg = get_config("granite-moe-1b-a400m")
    r = sharding_policy(cfg, SHAPES["train_4k"], mesh)
    assert r.strategy == "dp_ep"
    assert r.physical("experts") == "model"
    # decode -> tp path with split-KV for small kv_heads
    cfg = get_config("qwen2.5-3b")
    r = sharding_policy(cfg, SHAPES["decode_32k"], mesh)
    assert r.physical("kv_seq") in ("model", None)


def test_policy_long_context_sp():
    cfg = get_config("zamba2-2.7b")
    mesh = fake_mesh((4, 4))
    r = sharding_policy(cfg, SHAPES["long_500k"], mesh)
    assert r.physical("batch") is None  # batch=1
    kv = r.physical("kv_seq")
    assert kv is not None  # KV split across the mesh


def test_moe_ep_matches_local():
    """Expert-parallel shard_map MoE == local MoE on a 1-device mesh."""
    from repro.models import layers as L
    from repro.parallel.sharding import axis_rules
    from repro.launch.mesh import single_device_mesh
    from repro.configs.granite_moe_1b_a400m import smoke

    cfg = smoke()
    key = jax.random.PRNGKey(0)
    p = L.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model))
    out_local, aux_local = L.moe(p, cfg, x)

    mesh = single_device_mesh()
    rules = AxisRules({"experts": "model", "batch": "data", "embed": None},
                      mesh)
    with mesh, axis_rules(rules):
        out_ep, aux_ep = L.moe(p, cfg, x)
    np.testing.assert_allclose(np.asarray(out_local), np.asarray(out_ep),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(float(aux_local), float(aux_ep), rtol=1e-5)
