# NOTE: no xla_force_host_platform_device_count here — smoke tests and
# benches run on the single real CPU device; only launch/dryrun.py forces
# 512 placeholder devices (in its own process).
import jax

jax.config.update("jax_enable_x64", False)
