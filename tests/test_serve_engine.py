"""Serving plane (DESIGN.md §10): the paged KV block pool reusing the PR 1
free-run index (invariants re-run at page-sized configurations), and the
continuous-batching engine — scheduling must never change tokens, only
when they are computed (continuous == static == preempted bit-for-bit).
"""
import random

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.pool import FreeRunIndex
from repro.serve import (ContinuousEngine, LMConfig, PagedKVCache,
                         PageExhausted, Request, RequestState,
                         make_zipf_requests)
from repro.serve import model as PM


# ---------------------------------------------------------------------------
# free-run index at page-sized configurations
# ---------------------------------------------------------------------------

def check_cache_index(cache):
    """The cache's index runs must equal a brute-force recomputation from
    ownership state (same invariant as tests/test_pool_index.py, with
    uids = page ids and a single (0, "page") bucket)."""
    owned = {p for pages in cache._pages.values() for p in pages}
    free = [p for p in range(1, cache.num_pages) if p not in owned]
    runs, start, prev = [], None, None
    for p in free:
        if start is None:
            start = prev = p
        elif p == prev + 1:
            prev = p
        else:
            runs.append((start, prev + 1))
            start = prev = p
    if start is not None:
        runs.append((start, prev + 1))
    assert cache.free_runs() == runs
    assert cache.free_pages == len(free)


@pytest.mark.parametrize("seed", range(25))
def test_page_index_invariants(seed):
    """Randomized alloc/append/free walk: the index stays byte-identical
    to brute force, page 0 is never handed out, and no page is owned by
    two sequences."""
    rng = random.Random(seed)
    ps = rng.choice([4, 8, 16])
    cache = PagedKVCache(num_pages=rng.choice([16, 33, 64]), page_size=ps,
                         n_layers=1, n_kv_heads=1, head_dim=4,
                         max_pages_per_seq=rng.choice([4, 8]))
    live = []
    for sid in range(60):
        op = rng.random()
        if op < 0.5:
            try:
                cache.alloc_seq(sid, rng.randint(0, 3 * ps))
                live.append(sid)
            except PageExhausted:
                pass
        elif op < 0.8 and live:
            grow = rng.choice(live)
            if cache.ensure_append(grow):
                cache.advance(grow)
        elif live:
            cache.free_seq(live.pop(rng.randrange(len(live))))
        check_cache_index(cache)
        owned = [p for pages in cache._pages.values() for p in pages]
        assert 0 not in owned, "null page leaked to a sequence"
        assert len(owned) == len(set(owned)), "page double-owned"
    for sid in list(live):
        cache.free_seq(sid)
        check_cache_index(cache)
    assert cache.free_runs() == [(1, cache.num_pages)], \
        "drained pool must merge into one run"


def test_page_allocator_is_the_pool_index():
    """No second allocator implementation: the cache's placement state IS
    a core FreeRunIndex instance."""
    cache = PagedKVCache(num_pages=8, page_size=4, n_layers=1,
                         n_kv_heads=1, head_dim=4)
    assert isinstance(cache._index, FreeRunIndex)


def test_best_fit_keeps_pages_contiguous():
    cache = PagedKVCache(num_pages=17, page_size=4, n_layers=1,
                         n_kv_heads=1, head_dim=4)
    cache.alloc_seq(0, 8)     # pages 1-2
    cache.alloc_seq(1, 16)    # pages 3-6
    cache.free_seq(0)         # hole of 2 at the front
    cache.alloc_seq(2, 8)     # best-fit: exactly the 2-page hole
    assert cache.seq_pages(2) == [1, 2]
    cache.alloc_seq(3, 12)    # 3 pages from the tail run
    assert cache.seq_pages(3) == [7, 8, 9]


def test_write_slot_and_table_padding():
    cache = PagedKVCache(num_pages=9, page_size=4, n_layers=1,
                         n_kv_heads=1, head_dim=4, max_pages_per_seq=3)
    cache.alloc_seq(5, 0)
    assert cache.ensure_append(5)
    page0 = cache.seq_pages(5)[0]
    assert cache.write_slot(5) == (page0, 0)
    for _ in range(4):
        assert cache.ensure_append(5)
        cache.advance(5)
    assert cache.seq_len(5) == 4
    assert len(cache.seq_pages(5)) == 1       # page exactly full
    assert cache.ensure_append(5)             # token 5 needs a new page
    assert len(cache.seq_pages(5)) == 2
    assert cache.write_slot(5) == (cache.seq_pages(5)[1], 0)
    table = cache.page_table([5, None], max_pages=3)
    assert table.shape == (2, 3)
    assert list(table[0][:2]) == cache.seq_pages(5)
    assert table[0][2] == 0 and (table[1] == 0).all()
    assert list(cache.kv_lens([5, None])) == [4, 0]


# ---------------------------------------------------------------------------
# engine scheduling
# ---------------------------------------------------------------------------

CFG = LMConfig()
PARAMS = PM.init(CFG, jax.random.PRNGKey(0))


def _requests(seed=1, n=8, max_new=(1, 12), prompt=(3, 9)):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, CFG.vocab, int(
                        rng.integers(*prompt))).astype(np.int32),
                    max_new_tokens=int(rng.integers(*max_new)))
            for i in range(n)]


def _engine(mode="continuous", lanes=4, num_pages=64, maxp=8):
    return ContinuousEngine(CFG, PARAMS, lanes=lanes, num_pages=num_pages,
                            max_pages_per_seq=maxp, mode=mode)


def _tokens(reqs):
    return {r.rid: list(r.generated) for r in reqs}


def test_continuous_equals_static_tokens():
    """The scheduler may only change *when* a token is computed, never
    its value: per-lane math is row-independent, so continuous batching
    is bit-identical to the static-batch baseline."""
    rc, rs = _requests(), _requests()
    ec, es = _engine("continuous"), _engine("static")
    ec.submit_many(rc)
    es.submit_many(rs)
    sc, ss = ec.run(), es.run()
    assert _tokens(rc) == _tokens(rs)
    assert all(r.state is RequestState.DONE for r in rc + rs)
    assert sc["generated_tokens"] == ss["generated_tokens"]
    assert sc["steps"] < ss["steps"], (
        "continuous batching must finish the ragged workload in fewer "
        f"lane-steps ({sc['steps']} vs {ss['steps']})")


def test_preempt_to_recompute_bit_exact():
    """Page exhaustion evicts the youngest sequence; its prompt + tokens
    so far re-enter as a recompute, and greedy decode regenerates the
    identical continuation — the token-history analogue of FlowOS-RM's
    checkpoint-preempt."""
    reqs = _requests(seed=3, n=6, max_new=(20, 30), prompt=(3, 7))
    big = _engine(num_pages=64)
    big.submit_many(reqs)
    big.run()
    expected = _tokens(reqs)

    reqs2 = _requests(seed=3, n=6, max_new=(20, 30), prompt=(3, 7))
    tight = _engine(num_pages=12)    # growth must evict someone
    tight.submit_many(reqs2)
    stats = tight.run()
    assert stats["preemptions"] > 0, "tight budget never preempted"
    assert any(r.prefills > 1 for r in reqs2), "no recompute happened"
    assert _tokens(reqs2) == expected
    assert tight.cache.used_pages == 0, "retired pages leaked"


def test_join_on_arrival_mid_run():
    """A request submitted while the engine decodes is admitted at the
    next step boundary (continuous), but waits for the batch to drain
    under static batching."""
    late = Request(rid=99, prompt=np.array([5, 6, 7], np.int32),
                   max_new_tokens=2)
    eng = _engine("continuous")           # 4 lanes, 3 running: one free
    eng.submit_many(_requests(seed=4, n=3, max_new=(6, 10)))
    for _ in range(3):
        eng.step()
    eng.submit(late)
    eng.step()
    assert late.state in (RequestState.PREFILL, RequestState.DECODE), \
        "continuous engine must admit on the next step"
    eng.run()
    assert late.state is RequestState.DONE

    late2 = Request(rid=99, prompt=np.array([5, 6, 7], np.int32),
                    max_new_tokens=2)
    st = _engine("static")
    st.submit_many(_requests(seed=4, n=3, max_new=(6, 10)))
    for _ in range(3):
        st.step()
    st.submit(late2)
    st.step()
    assert late2.state is RequestState.WAITING, \
        "static engine admitted into a live batch"
    st.run()
    assert late2.state is RequestState.DONE


def test_ingest_prefill_matches_streaming():
    """The disaggregated-prefill path (batch prompt pass + KV scatter,
    the PR 2 hop's payload) must continue exactly like inline streaming
    prefill."""
    prompts = np.random.default_rng(5).integers(
        0, CFG.vocab, (3, 6)).astype(np.int32)
    s_reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=6)
              for i in range(3)]
    eng = _engine(lanes=3)
    eng.submit_many(s_reqs)
    eng.run()

    k, v, last = PM.prefill(CFG, PARAMS, jnp.asarray(prompts))
    i_reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=6)
              for i in range(3)]
    eng2 = _engine(lanes=3)
    for i, r in enumerate(i_reqs):
        eng2.ingest_prefill(r, k[:, i], v[:, i], last[i])
    stats = eng2.run()
    assert _tokens(i_reqs) == _tokens(s_reqs)
    assert stats["ingested_tokens"] == 18
    assert stats["prefill_tokens"] == 0


def test_seq_cap_truncates_only_the_overgrown_request():
    """A sequence that outgrows max_pages_per_seq is truncated (retired
    with what it has) — it must NOT evict innocent neighbours, and the
    rest of the workload completes untouched. A prompt that can never
    fit the cap is rejected at admission instead of wedging the queue."""
    from repro.serve import SequenceCapExceeded
    # maxp=2 (16-token cap), plenty of pool pages
    eng = ContinuousEngine(CFG, PARAMS, lanes=2, num_pages=32,
                           max_pages_per_seq=2)
    hog = Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                  max_new_tokens=100)           # wants 104 tokens
    ok = Request(rid=1, prompt=np.arange(4, dtype=np.int32),
                 max_new_tokens=5)
    eng.submit_many([hog, ok])
    stats = eng.run()
    assert stats["truncated"] == 1
    assert stats["preemptions"] == 0, "cap truncation evicted a neighbour"
    assert hog.state is RequestState.DONE
    # cap token-slots minus prompt, +1: the last generated token is
    # appended by the final step but never written back to the cache
    assert len(hog.generated) == 2 * CFG.page_size - 4 + 1
    assert len(ok.generated) == 5
    assert eng.cache.used_pages == 0

    # un-fittable prompt: rejected, queue keeps moving
    eng2 = ContinuousEngine(CFG, PARAMS, lanes=2, num_pages=32,
                            max_pages_per_seq=2)
    bad = Request(rid=0, prompt=np.zeros(3 * CFG.page_size, np.int32),
                  max_new_tokens=2)
    good = Request(rid=1, prompt=np.arange(4, dtype=np.int32),
                   max_new_tokens=3)
    eng2.submit_many([bad, good])
    stats2 = eng2.run()
    assert stats2["rejected"] == 1
    assert bad.state is RequestState.DONE and bad.generated == []
    assert len(good.generated) == 3

    # the cache-level signal is distinct from pool exhaustion
    cache = PagedKVCache(num_pages=32, page_size=4, n_layers=1,
                         n_kv_heads=1, head_dim=4, max_pages_per_seq=1)
    cache.alloc_seq(0, 4)
    with pytest.raises(SequenceCapExceeded):
        cache.ensure_append(0)


def test_page_budget_too_small_fails_loud():
    """A budget that cannot hold even one sequence must raise, not
    livelock on preempt-readmit cycles."""
    eng = ContinuousEngine(CFG, PARAMS, lanes=2, num_pages=3,
                           max_pages_per_seq=8)
    eng.submit(Request(rid=0,
                       prompt=np.zeros(2 * CFG.page_size, np.int32),
                       max_new_tokens=4))
    with pytest.raises(PageExhausted):
        eng.run()


def test_admission_watermark_protects_running():
    """Joining sequences must not evict running ones: admission requires
    the whole prompt (+1 token) in currently-free pages."""
    eng = _engine(lanes=4, num_pages=8, maxp=4)   # 7 usable pages
    eng.submit_many([Request(rid=i, prompt=np.zeros(
        2 * CFG.page_size, np.int32), max_new_tokens=2)
        for i in range(4)])
    stats = eng.run()
    assert stats["preemptions"] == 0
    assert stats["generated_tokens"] == 8


def test_slice_hbm_accounting():
    from repro.core import DevicePool
    from repro.core.slice import Slice
    pool = DevicePool.virtual(2)
    s = Slice(name="serve", pool=pool, n_devices=1)
    s.attach_device()
    eng = ContinuousEngine(CFG, PARAMS, lanes=2, num_pages=16,
                           slice_=s)
    assert s.hbm["kv_pages"] == eng.cache.hbm_bytes
    assert s.hbm_bytes() == eng.cache.hbm_bytes > 0
    s.teardown()
    assert s.hbm_bytes() == 0, "destroy_machine must drop reservations"


def test_zipf_workload_shape():
    reqs = make_zipf_requests(CFG.vocab, np.random.default_rng(0), 200, 8,
                              max_new_cap=64)
    lens = [r.max_new_tokens for r in reqs]
    assert min(lens) >= 1 and max(lens) <= 64
    assert np.mean(lens) < np.max(lens) / 3, \
        "workload is not ragged enough to exercise the straggler effect"


# ---------------------------------------------------------------------------
# launch-driver integration
# ---------------------------------------------------------------------------

def test_run_serving_continuous_slice_path():
    from repro.launch.serve import run_serving_continuous
    out = run_serving_continuous(n_requests=8, lanes=4, prompt_len=4,
                                 max_new_cap=8)
    assert out["continuous"]["generated_tokens"] == \
        out["static"]["generated_tokens"] > 0
    assert out["hbm_bytes"] > 0
    assert out["breakdown"]["run_task"] > 0


def test_run_serving_continuous_disaggregated_prefill():
    """--microbatches > 1: prompt KV is computed on the prefill sub-slice
    and hops the PR 2 fabric into the decode engine; tokens must match
    the single-slice path."""
    from repro.launch.serve import run_serving_continuous
    base = run_serving_continuous(n_requests=8, lanes=4, prompt_len=4,
                                  max_new_cap=8, compare_static=False)
    out = run_serving_continuous(n_requests=8, lanes=4, prompt_len=4,
                                 max_new_cap=8, microbatches=4)
    c, b = out["continuous"], base["continuous"]
    assert c["generated_tokens"] == b["generated_tokens"]
    assert c["ingested_tokens"] == 8 * 4      # every prompt via the hop
    assert c["prefill_tokens"] == 0
    assert out["transfers"]["hops"] >= 4      # one per prefill microbatch
    assert out["transfers"]["bytes"] > 0
