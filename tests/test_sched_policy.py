"""Scheduling policy layer (DESIGN.md §9): strict-priority pop with
anti-starvation aging, gang-admission atomicity under a shared pool,
cooperative preemption (checkpoint → teardown → requeue → restore), the
mid-preemption-death FAILED guarantee, and defragmentation."""
import random
import threading
import time

import pytest

from repro.core import (DevicePool, FlowOSRM, JobSpec, Preempted, TaskSpec)
from repro.core.job import JobStatus


def _sleep_job(name, n, dur=0.02, priority=0):
    return JobSpec(name=name, priority=priority, tasks=[TaskSpec(
        name="t", n_devices=n, task_fn=lambda s: time.sleep(dur))])


def _coop_task(stop, result=None, poll_s=0.002):
    """Cooperative task: blocks on the slice's preempt event, yields via
    Preempted, returns ``result`` once ``stop`` fires."""
    def task(s):
        while not stop.is_set():
            if s.wait_preempt(poll_s):
                raise Preempted()
        return result
    return task


# ---------------------------------------------------------------------------
# priority + aging
# ---------------------------------------------------------------------------

def test_priority_pop_beats_fifo_order():
    """With the pool busy, a later-submitted high-priority job must start
    before an earlier low-priority one once capacity frees."""
    pool = DevicePool.virtual(8)
    rm = FlowOSRM(pool)
    blocker = rm.submit(_sleep_job("blocker", 8, 0.05))
    rm.schedule_once()
    lo = rm.submit(_sleep_job("lo", 8, 0.0))
    hi = rm.submit(_sleep_job("hi", 8, 0.0, priority=5))
    rm.run_until_idle()
    ids = (blocker, lo, hi)
    assert all(rm.status(i)["status"] == "done" for i in ids)
    assert (rm.status(hi)["start_time"] < rm.status(lo)["start_time"])


def test_task_priority_raises_job_priority():
    spec = JobSpec(name="j", priority=1, tasks=[
        TaskSpec(name="a", n_devices=1, priority=7),
        TaskSpec(name="b", n_devices=1)])
    assert spec.effective_priority == 7
    spec2 = JobSpec.from_dict(spec.to_dict())
    assert spec2.effective_priority == 7
    assert spec2.preemptible is False


def test_aging_unblocks_starved_job():
    """A low-priority job that has waited >= aging_s * gap must outrank a
    fresh higher-base-priority job (anti-starvation)."""
    pool = DevicePool.virtual(8)
    rm = FlowOSRM(pool, aging_s=0.02, aging_cap=10)
    blocker = rm.submit(_sleep_job("blocker", 8, 0.3))
    rm.schedule_once()
    old_lo = rm.submit(_sleep_job("old_lo", 8, 0.0))
    time.sleep(0.25)  # old_lo ages ~10 levels (capped)
    fresh_mid = rm.submit(_sleep_job("fresh_mid", 8, 0.0, priority=3))
    rm.run_until_idle()
    assert (rm.status(old_lo)["start_time"]
            < rm.status(fresh_mid)["start_time"])
    assert rm.status(blocker)["status"] == "done"


@pytest.mark.parametrize("seed", range(8))
def test_max_priority_places_within_k_completions(seed):
    """Starvation property: a max-priority job (base gap > aging_cap, so
    no amount of waiting bridges it) must place before ANY lower-priority
    job that was still queued when it arrived — i.e. within at most
    pool/width completions of the already-running set."""
    rng = random.Random(seed)
    pool = DevicePool.virtual(16)
    rm = FlowOSRM(pool, aging_s=0.005, aging_cap=10)
    small = [rm.submit(_sleep_job(f"s{i}", 4, rng.uniform(0.005, 0.03)))
             for i in range(12)]
    rm.schedule_once()          # 4 smalls start; 8 queued
    top = rm.submit(_sleep_job("top", 16, 0.0, priority=100))
    rm.run_until_idle()
    assert all(rm.status(i)["status"] == "done" for i in small + [top])
    top_submit = rm.status(top)["submit_time"]
    top_start = rm.status(top)["start_time"]
    late_small_starts = [
        rm.status(i)["start_time"] for i in small
        if rm.status(i)["start_time"] > top_submit]
    # every small that started after top arrived must have started after
    # top did (top is never overtaken) -> top placed within the <=4
    # completions of the smalls that were already running
    assert all(st >= top_start for st in late_small_starts), (
        f"seed={seed}: max-priority job was overtaken")


# ---------------------------------------------------------------------------
# gang admission
# ---------------------------------------------------------------------------

def test_gang_admission_atomic_under_two_rms():
    """Two RMs race for one 8-device pool with 2-task gangs: a RUNNING
    job must always hold every task lease (sampled under the RM lock),
    and the rollback path must leak nothing."""
    pool = DevicePool.virtual(8)
    rms = [FlowOSRM(pool), FlowOSRM(pool)]
    violations = []
    stop_mon = threading.Event()

    def monitor():
        # a RUNNING job must have been admitted whole: one slice per task
        # (each slice releases its lease as its task completes, so lease
        # presence is not the invariant — slice-set completeness is), and
        # an ALLOCATING job must never be visible at all, since gang
        # admission commits or rolls back entirely under the RM lock
        while not stop_mon.is_set():
            for rm in rms:
                with rm._lock:
                    for r in rm._jobs.values():
                        if (r.status == JobStatus.RUNNING
                                and len(r.slices) != len(r.spec.tasks)):
                            violations.append(("partial", r.spec.name))
                        if r.status == JobStatus.ALLOCATING:
                            violations.append(("allocating", r.spec.name))
            time.sleep(0.001)

    mon = threading.Thread(target=monitor, daemon=True)
    mon.start()

    def drive(rm, tag):
        specs = [JobSpec(name=f"{tag}{i}", tasks=[
            TaskSpec(name="a", n_devices=3,
                     task_fn=lambda s: time.sleep(0.001)),
            TaskSpec(name="b", n_devices=3,
                     task_fn=lambda s: time.sleep(0.001)),
        ]) for i in range(12)]
        rm.submit_many(specs)
        rm.run_until_idle(timeout_s=60)

    threads = [threading.Thread(target=drive, args=(rm, tag), daemon=True)
               for rm, tag in zip(rms, "AB")]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=90)
    stop_mon.set()
    mon.join(timeout=5)
    assert not any(t.is_alive() for t in threads), "cross-RM deadlock"
    assert violations == [], f"partial gangs observed RUNNING: {violations}"
    for rm in rms:
        assert all(r.status == JobStatus.DONE for r in rm._jobs.values())
        rm.close()
    assert pool.utilization() == 0.0


# ---------------------------------------------------------------------------
# cooperative preemption
# ---------------------------------------------------------------------------

def test_preemption_end_to_end_with_checkpoint(tmp_path):
    """High-priority arrival preempts a low-priority preemptible job; the
    victim checkpoints, requeues, and resumes from its saved step."""
    pool = DevicePool.virtual(16)
    rm = FlowOSRM(pool)
    starts = []

    def victim_task(s):
        state = s.ckpt.restore_latest(default={"i": 0})
        i = int(state["i"])
        starts.append(i)
        while i < 30:
            if s.wait_preempt(0.002):
                raise Preempted(state={"i": i}, step=i)
            i += 1
        return i

    victim = rm.submit(JobSpec(name="victim", preemptible=True, tasks=[
        TaskSpec(name="t", n_devices=16, checkpoint_dir=str(tmp_path),
                 task_fn=victim_task)]))
    rm.schedule_once()
    time.sleep(0.02)  # let the victim make progress past step 0
    hi = rm.submit(JobSpec(name="hi", priority=50, tasks=[
        TaskSpec(name="t", n_devices=16, task_fn=lambda s: "done")]))
    rec_hi = rm.wait(hi, timeout_s=30)
    assert rec_hi.status == JobStatus.DONE
    # bounded time-to-placement: preemption, not victim completion
    assert rec_hi.start_time - rec_hi.submit_time < 5.0
    rm.run_until_idle(timeout_s=30)
    st = rm.status(victim)
    assert st["status"] == "done"
    assert st["preemptions"] == 1
    assert len(starts) == 2 and starts[0] == 0 and starts[1] > 0, starts
    assert pool.utilization() == 0.0
    kinds = [e[2] for e in rm.events if e[1] == "victim"]
    for ev in ("preempt_requested", "preempting", "preempted"):
        assert ev in kinds
    rm.close()


def test_preemption_never_touches_non_preemptible():
    """A high-priority job blocked only by non-preemptible leases must
    wait for normal completion — no preempt request is ever issued."""
    pool = DevicePool.virtual(8)
    rm = FlowOSRM(pool)
    lo = rm.submit(_sleep_job("lo", 8, 0.05))
    rm.schedule_once()
    hi = rm.submit(_sleep_job("hi", 8, 0.0, priority=99))
    rm.run_until_idle()
    assert rm.status(lo)["status"] == "done"
    assert rm.status(lo)["preemptions"] == 0
    assert not any(e[2] == "preempt_requested" for e in rm.events)
    assert rm.status(hi)["start_time"] >= rm.status(lo)["end_time"] - 0.02


def test_no_preemption_when_it_cannot_unblock():
    """If even preempting every eligible victim cannot cover the deficit,
    nothing is preempted (shedding work without unblocking is waste)."""
    pool = DevicePool.virtual(8)
    rm = FlowOSRM(pool)
    stop = threading.Event()
    coop = rm.submit(JobSpec(name="coop", preemptible=True, tasks=[
        TaskSpec(name="t", n_devices=2, task_fn=_coop_task(stop))]))
    hard = rm.submit(_sleep_job("hard", 6, 0.08))
    rm.schedule_once()
    # needs 10 > 8 total: never placeable; preempting coop gains nothing
    huge = rm.submit(_sleep_job("huge", 10, 0.0, priority=99))
    with pytest.raises(TimeoutError):
        rm.run_until_idle(timeout_s=0.3)
    assert rm.status(coop)["status"] == "running"
    assert not any(e[2] == "preempt_requested" for e in rm.events)
    assert rm.cancel(huge)
    stop.set()
    rm.run_until_idle(timeout_s=30)
    assert rm.status(coop)["status"] == "done"
    assert rm.status(hard)["status"] == "done"
    rm.close()


def test_equal_priority_jobs_never_preempt_each_other():
    """Aging orders the queue but never grants preemption rights: a
    queued equal-base-priority job must not preempt a running peer no
    matter how long it has aged (else requeue ping-pong livelock)."""
    pool = DevicePool.virtual(4)
    rm = FlowOSRM(pool, aging_s=0.01, aging_cap=10)
    stop = threading.Event()
    a = rm.submit(JobSpec(name="a", preemptible=True, tasks=[
        TaskSpec(name="t", n_devices=4, task_fn=_coop_task(stop, "a"))]))
    rm.schedule_once()
    rm.submit(JobSpec(name="b", preemptible=True, tasks=[
        TaskSpec(name="t", n_devices=4, task_fn=_coop_task(stop, "b"))]))
    time.sleep(0.15)   # b ages far past a's base priority
    rm.schedule_once()
    assert not any(e[2] == "preempt_requested" for e in rm.events)
    assert rm.status(a)["status"] == "running"
    stop.set()
    rm.run_until_idle(timeout_s=30)
    assert all(j["preemptions"] == 0 for j in rm.jobs())
    rm.close()


def test_preemption_skips_victims_of_useless_kind():
    """Victim choice must not shed jobs whose devices cannot reduce the
    blocked job's deficit: a tpu-holding preemptible job is left alone
    when the deficit is gpu-only and a gpu victim suffices."""
    pool = DevicePool.virtual(16, kinds={(0, 8): "gpu", (8, 16): "tpu"})
    rm = FlowOSRM(pool)
    stop = threading.Event()
    tpu_job = rm.submit(JobSpec(name="tpu_j", preemptible=True, tasks=[
        TaskSpec(name="t", n_devices=4, kind="tpu",
                 task_fn=_coop_task(stop, "t"))]))
    gpu_job = rm.submit(JobSpec(name="gpu_j", preemptible=True, tasks=[
        TaskSpec(name="t", n_devices=8, kind="gpu",
                 task_fn=_coop_task(stop, "g"))]))
    rm.schedule_once()
    hi = rm.submit(JobSpec(name="hi", priority=10, tasks=[
        TaskSpec(name="t", n_devices=8, kind="gpu",
                 task_fn=lambda s: None)]))
    rec = rm.wait(hi, timeout_s=30)
    assert rec.status == JobStatus.DONE
    stop.set()
    rm.run_until_idle(timeout_s=30)
    # the tpu job (sorts first: fewer held) contributes nothing to the
    # gpu deficit and must never have been asked to yield
    assert rm.status(tpu_job)["preemptions"] == 0
    assert rm.status(gpu_job)["preemptions"] == 1
    rm.close()


def test_mid_preemption_death_surfaces_failed_not_hang():
    """Satellite fix: a job that dies mid-preemption (here: it yields
    checkpoint state but has no checkpoint_dir to save it to) must end
    FAILED with leases released — wait()/run_until_idle() must return,
    not wedge on a condition variable that never fires."""
    pool = DevicePool.virtual(8)
    rm = FlowOSRM(pool)

    def bad_task(s):
        while True:
            if s.wait_preempt(0.002):
                raise Preempted(state={"x": 1})  # no checkpoint_dir

    bad = rm.submit(JobSpec(name="bad", preemptible=True, tasks=[
        TaskSpec(name="t", n_devices=8, task_fn=bad_task)]))
    rm.schedule_once()
    hi = rm.submit(_sleep_job("hi", 8, 0.0, priority=9))
    rec = rm.wait(hi, timeout_s=30)
    assert rec.status == JobStatus.DONE
    rm.run_until_idle(timeout_s=30)   # must NOT hang on the dead job
    st = rm.status(bad)
    assert st["status"] == "failed"
    assert "mid-preemption" in st["error"]
    assert st["end_time"] is not None
    assert pool.utilization() == 0.0
    rm.close()


def test_mid_preemption_unsaveable_state_fails(tmp_path):
    """Same guarantee when the checkpoint write itself explodes."""
    class Unsaveable:
        def __array__(self, *a, **k):
            raise RuntimeError("cannot snapshot")

    pool = DevicePool.virtual(4)
    rm = FlowOSRM(pool)

    def bad_task(s):
        while True:
            if s.wait_preempt(0.002):
                raise Preempted(state={"x": Unsaveable()})

    bad = rm.submit(JobSpec(name="bad", preemptible=True, tasks=[
        TaskSpec(name="t", n_devices=4, checkpoint_dir=str(tmp_path),
                 task_fn=bad_task)]))
    rm.schedule_once()
    rm.submit(_sleep_job("hi", 4, 0.0, priority=9))
    rm.run_until_idle(timeout_s=30)
    st = rm.status(bad)
    assert st["status"] == "failed" and "mid-preemption" in st["error"]
    assert pool.utilization() == 0.0
    rm.close()


def test_preempted_victim_does_not_outrank_its_preemptor():
    """Requeue restarts the aging clock: a long-RUNNING victim must not
    come back with a stale aging boost that outranks the higher-base job
    it just yielded to (preempt/requeue livelock). Exactly one
    preemption may occur."""
    pool = DevicePool.virtual(8)
    rm = FlowOSRM(pool, aging_s=0.01, aging_cap=10)
    stop = threading.Event()
    v = rm.submit(JobSpec(name="v", preemptible=True, tasks=[
        TaskSpec(name="t", n_devices=8, task_fn=_coop_task(stop, "v"))]))
    rm.schedule_once()
    time.sleep(0.15)    # victim alive >> aging_s * aging_cap
    hi = rm.submit(_sleep_job("hi", 8, 0.02, priority=5))
    rec = rm.wait(hi, timeout_s=20)
    assert rec.status == JobStatus.DONE
    stop.set()
    rm.run_until_idle(timeout_s=30)
    assert rm.status(v)["status"] == "done"
    assert rm.status(v)["preemptions"] == 1, (
        "victim bounced: stale aging boost reclaimed the freed capacity")
    rm.close()


def test_preempt_requested_clears_when_victim_finishes_anyway():
    """A victim that completes on its own instead of yielding must not
    read as still-yielding afterwards: quiescent() (and the preemption
    deficit accounting) consult the flag."""
    pool = DevicePool.virtual(4)
    rm = FlowOSRM(pool)
    ev = threading.Event()

    def oblivious(s):
        ev.wait(10)     # never checks preempt_requested
        return "done"

    j = rm.submit(JobSpec(name="j", preemptible=True, tasks=[
        TaskSpec(name="t", n_devices=4, task_fn=oblivious)]))
    rm.schedule_once()
    assert rm.preempt_job(j)
    ev.set()
    rm.run_until_idle(timeout_s=30)
    assert rm.status(j)["status"] == "done"
    assert rm.quiescent(), "finished victim still reads as yielding"
    rm.close()


def test_operator_preempt_job_api():
    pool = DevicePool.virtual(4)
    rm = FlowOSRM(pool)
    stop = threading.Event()
    j = rm.submit(JobSpec(name="j", preemptible=True, tasks=[
        TaskSpec(name="t", n_devices=4, task_fn=_coop_task(stop, "ok"))]))
    rm.schedule_once()
    assert rm.preempt_job(j)
    assert not rm.preempt_job(j)  # already requested
    stop.set()
    rm.run_until_idle(timeout_s=30)
    assert rm.status(j)["status"] == "done"
    assert rm.status(j)["preemptions"] == 1
    rm.close()


# ---------------------------------------------------------------------------
# defragmentation
# ---------------------------------------------------------------------------

def _checkerboard(pool_size, lease_n, stop, go, relocatable=True):
    """Alternating held (relocatable) / released leases."""
    specs = []
    for i in range(pool_size // lease_n):
        if i % 2 == 0:
            specs.append(JobSpec(
                name=f"keep{i}", preemptible=True, relocatable=relocatable,
                tasks=[TaskSpec(name="t", n_devices=lease_n,
                                task_fn=_coop_task(stop))]))
        else:
            specs.append(JobSpec(name=f"gap{i}", tasks=[
                TaskSpec(name="t", n_devices=lease_n,
                         task_fn=lambda s: go.wait(30))]))
    return specs


def _drive_defrag(rm, pool, rounds=32, **kw):
    moves = 0
    for _ in range(rounds):
        m = rm.defragment(**kw)
        moves += m
        deadline = time.perf_counter() + 5
        while time.perf_counter() < deadline:
            rm.schedule_once()
            if rm.quiescent():
                break
            time.sleep(0.002)
        if m == 0:
            break
    return moves


def test_defragment_recoalesces_checkerboard():
    pool = DevicePool.virtual(64, devices_per_pod=64)
    rm = FlowOSRM(pool, relocation_limit=8)
    stop, go = threading.Event(), threading.Event()
    ids = rm.submit_many(_checkerboard(64, 4, stop, go))
    rm.schedule_once()
    go.set()
    deadline = time.perf_counter() + 5
    while time.perf_counter() < deadline:
        if all(rm.status(i)["status"] == "done" for i in ids[1::2]):
            break
        time.sleep(0.002)
    frag0, largest0 = pool.fragmentation(), pool.largest_free_run()
    assert frag0 > 0.5 and largest0 == 4
    moves = _drive_defrag(rm, pool, max_moves=4, frag_threshold=0.2)
    assert moves > 0
    assert pool.largest_free_run() >= 4 * largest0
    assert pool.fragmentation() < frag0
    stop.set()
    rm.run_until_idle(timeout_s=30)
    assert pool.utilization() == 0.0
    rm.close()


def test_defragment_skips_non_relocatable():
    pool = DevicePool.virtual(32, devices_per_pod=32)
    rm = FlowOSRM(pool)
    stop, go = threading.Event(), threading.Event()
    rm.submit_many(_checkerboard(32, 4, stop, go, relocatable=False))
    rm.schedule_once()
    go.set()
    time.sleep(0.05)
    assert pool.fragmentation() > 0.5
    assert rm.defragment(max_moves=8, frag_threshold=0.2) == 0
    assert not any(e[2] == "relocate_requested" for e in rm.events)
    stop.set()
    rm.run_until_idle(timeout_s=30)
    rm.close()


def test_defragment_respects_relocation_limit():
    pool = DevicePool.virtual(32, devices_per_pod=32)
    rm = FlowOSRM(pool, relocation_limit=1)
    stop, go = threading.Event(), threading.Event()
    ids = rm.submit_many(_checkerboard(32, 4, stop, go))
    rm.schedule_once()
    go.set()
    deadline = time.perf_counter() + 5
    while time.perf_counter() < deadline:
        if all(rm.status(i)["status"] == "done" for i in ids[1::2]):
            break
        time.sleep(0.002)
    _drive_defrag(rm, pool, max_moves=8, frag_threshold=0.0)
    assert all(rm.status(i)["relocations"] <= 1 for i in ids[::2])
    stop.set()
    rm.run_until_idle(timeout_s=30)
    rm.close()


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(20))
def test_randomized_fragment_then_compact_invariants(seed):
    """Randomized fragmentation → compaction: whatever the layout, the
    pass must never lose capacity, never worsen the largest free run,
    and leave the free-run index consistent (brute-force check)."""
    from tests.test_pool_index import check_index

    rng = random.Random(1000 + seed)
    lease_n = rng.choice([2, 4])
    pool_size = rng.choice([32, 64])
    pool = DevicePool.virtual(pool_size, devices_per_pod=pool_size)
    rm = FlowOSRM(pool, relocation_limit=4)
    stop, go = threading.Event(), threading.Event()
    specs = []
    for i in range(pool_size // lease_n):
        if rng.random() < 0.55:
            specs.append(JobSpec(
                name=f"keep{i}", preemptible=True, relocatable=True,
                tasks=[TaskSpec(name="t", n_devices=lease_n,
                                task_fn=_coop_task(stop))]))
        else:
            specs.append(JobSpec(name=f"gap{i}", tasks=[
                TaskSpec(name="t", n_devices=lease_n,
                         task_fn=lambda s: go.wait(30))]))
    ids = rm.submit_many(specs)
    rm.schedule_once()
    go.set()
    gap_ids = [i for i, sp in zip(ids, specs) if sp.name.startswith("gap")]
    deadline = time.perf_counter() + 10
    while time.perf_counter() < deadline:
        if all(rm.status(i)["status"] == "done" for i in gap_ids):
            break
        time.sleep(0.002)
    free0 = pool.free_count()
    largest0 = pool.largest_free_run()
    check_index(pool)
    _drive_defrag(rm, pool, max_moves=4, frag_threshold=0.1)
    check_index(pool)
    assert pool.free_count() == free0, "compaction lost/gained capacity"
    assert pool.largest_free_run() >= largest0, (
        f"seed={seed}: compaction shrank the largest free run")
    stop.set()
    rm.run_until_idle(timeout_s=30)
    check_index(pool)
    assert pool.utilization() == 0.0
    rm.close()
