"""Paged decode kernel (DESIGN.md §10): numerical equivalence to the
contiguous decode_attention kernel across randomized page tables, ragged
kv_len (block-boundary edges included), sliding windows and softcap —
plus the jnp twin the CPU engine jits, and null-page content isolation.
"""
import random

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention_fwd
from repro.kernels.paged_attention import (gather_kv, paged_attention_jnp,
                                           paged_decode_attention_fwd)


def scatter_to_pages(k, v, ps, rng):
    """Scatter contiguous (B, Hkv, T, D) KV into randomly permuted pool
    pages; returns (k_pages, v_pages, page_table) with page 0 reserved
    as the null page."""
    B, Hkv, T, D = k.shape
    maxp = T // ps
    num_pages = B * maxp + 1
    order = list(range(1, num_pages))
    rng.shuffle(order)
    table = np.asarray(order, np.int32).reshape(B, maxp)
    k_pages = np.zeros((num_pages, Hkv, ps, D), np.float32)
    v_pages = np.zeros_like(k_pages)
    for b in range(B):
        for j in range(maxp):
            k_pages[table[b, j]] = np.asarray(k[b, :, j * ps:(j + 1) * ps])
            v_pages[table[b, j]] = np.asarray(v[b, :, j * ps:(j + 1) * ps])
    return jnp.asarray(k_pages), jnp.asarray(v_pages), jnp.asarray(table)


def contiguous_ref(q, k, v, kv_len, q_pos, **kw):
    """Per-sequence contiguous decode kernel (scalar q_pos each) — the
    ground truth the paged kernel must reproduce bit-for-tolerance."""
    outs = [decode_attention_fwd(q[b:b + 1], k[b:b + 1], v[b:b + 1],
                                 kv_len[b:b + 1], q_pos[b:b + 1], **kw)
            for b in range(q.shape[0])]
    return jnp.concatenate(outs, axis=0)


def make_case(seed, B=3, Hq=4, Hkv=2, D=32, ps=16, maxp=6):
    rng = random.Random(seed)
    T = maxp * ps
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Hq, 1, D))
    k = jax.random.normal(ks[1], (B, Hkv, T, D))
    v = jax.random.normal(ks[2], (B, Hkv, T, D))
    # ragged lengths biased onto page boundaries (the edge that breaks
    # naive block masking): exactly-on, one-off, and uniform draws
    lens = []
    for _ in range(B):
        edge = ps * rng.randint(1, maxp)
        lens.append(rng.choice(
            [edge, max(edge - 1, 1), min(edge + 1, T),
             rng.randint(1, T)]))
    kv_len = jnp.asarray(lens, jnp.int32)
    q_pos = kv_len - 1          # each lane decodes at its own position
    k_pages, v_pages, table = scatter_to_pages(k, v, ps, rng)
    return q, k, v, kv_len, q_pos, k_pages, v_pages, table, ps


@pytest.mark.parametrize("seed", range(4))
def test_paged_matches_contiguous_randomized(seed):
    q, k, v, kv_len, q_pos, kp, vp, table, ps = make_case(seed)
    exp = contiguous_ref(q, k, v, kv_len, q_pos, bkv=ps)
    out = paged_decode_attention_fwd(q, kp, vp, table, kv_len, q_pos)
    twin = paged_attention_jnp(q, kp, vp, table, kv_len, q_pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(twin), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window,softcap", [(24, None), (None, 20.0),
                                            (24, 20.0)])
def test_paged_window_softcap(window, softcap):
    q, k, v, kv_len, q_pos, kp, vp, table, ps = make_case(7)
    kw = dict(window=window, softcap=softcap)
    exp = contiguous_ref(q, k, v, kv_len, q_pos, bkv=ps, **kw)
    out = paged_decode_attention_fwd(q, kp, vp, table, kv_len, q_pos, **kw)
    twin = paged_attention_jnp(q, kp, vp, table, kv_len, q_pos, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(twin), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)


def test_paged_gqa_and_mha_groups():
    for Hq, Hkv in [(4, 4), (8, 2), (3, 1)]:
        q, k, v, kv_len, q_pos, kp, vp, table, ps = make_case(
            11, Hq=Hq, Hkv=Hkv, maxp=4)
        exp = contiguous_ref(q, k, v, kv_len, q_pos, bkv=ps)
        out = paged_decode_attention_fwd(q, kp, vp, table, kv_len, q_pos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   atol=2e-5, rtol=2e-5)


def test_null_page_content_is_never_read():
    """Padded table slots point at page 0; poisoning it (and every
    unreferenced page) must not change any output — the kv_len mask, not
    page contents, is the correctness boundary. This is what makes the
    engine's null-page write trick safe."""
    q, k, v, kv_len, q_pos, kp, vp, table, ps = make_case(13)
    # shorten every sequence so trailing table slots are dead, then
    # repoint the dead slots at the null page like the engine does
    kv_len = jnp.minimum(kv_len, 2 * ps - 1)
    q_pos = kv_len - 1
    table = np.asarray(table).copy()
    table[:, 2:] = 0
    table = jnp.asarray(table)
    base = paged_attention_jnp(q, kp, vp, table, kv_len, q_pos)
    base_pal = paged_decode_attention_fwd(q, kp, vp, table, kv_len, q_pos)
    live = np.unique(np.asarray(table[:, :2]))
    poison_k = np.asarray(kp).copy()
    poison_v = np.asarray(vp).copy()
    dead = [p for p in range(kp.shape[0]) if p not in live]
    poison_k[dead] = 1e9
    poison_v[dead] = -1e9
    out = paged_attention_jnp(q, jnp.asarray(poison_k),
                              jnp.asarray(poison_v), table, kv_len, q_pos)
    out_pal = paged_decode_attention_fwd(
        q, jnp.asarray(poison_k), jnp.asarray(poison_v), table, kv_len,
        q_pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               atol=0, rtol=0)
    np.testing.assert_allclose(np.asarray(out_pal), np.asarray(base_pal),
                               atol=2e-5, rtol=2e-5)


def test_gather_kv_roundtrip():
    """gather_kv through the page table reassembles the contiguous
    cache exactly."""
    _, k, v, _, _, kp, vp, table, ps = make_case(17)
    np.testing.assert_array_equal(np.asarray(gather_kv(kp, table)),
                                  np.asarray(k))
    np.testing.assert_array_equal(np.asarray(gather_kv(vp, table)),
                                  np.asarray(v))
