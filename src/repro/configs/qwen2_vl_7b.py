"""qwen2-vl-7b [vlm] — M-RoPE, dynamic-resolution backbone; vision tower
stubbed to precomputed patch embeddings per the assignment
[arXiv:2409.12191]."""
from repro.models.config import ModelConfig
from repro.models.registry import register_config


@register_config("qwen2-vl-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b",
        family="vlm",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        head_dim=128,
        d_ff=18944,
        vocab_size=152_064,
        qkv_bias=True,
        rope_kind="mrope",
        mrope_sections=(16, 24, 24),  # (t, h, w) split of head_dim//2
        rope_theta=1_000_000.0,
        n_vision_patches=256,  # stub image grid at sequence start
        act="silu",
        tie_embeddings=False,
        remat="full",
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="qwen2-vl-7b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        mrope_sections=(2, 3, 3), n_vision_patches=4, remat="none")
