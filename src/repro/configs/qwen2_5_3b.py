"""qwen2.5-3b [dense] — GQA (16Q/2KV), QKV bias [hf:Qwen/Qwen2.5-3B]."""
from repro.models.config import ModelConfig
from repro.models.registry import register_config


@register_config("qwen2.5-3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b",
        family="dense",
        n_layers=36,
        d_model=2048,
        n_heads=16,
        n_kv_heads=2,
        head_dim=128,
        d_ff=11008,
        vocab_size=151_936,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        act="silu",
        tie_embeddings=True,
        remat="full",
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="qwen2.5-3b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256, remat="none")
