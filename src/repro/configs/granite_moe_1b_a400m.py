"""granite-moe-1b-a400m [moe] — 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.models.config import ModelConfig
from repro.models.registry import register_config


@register_config("granite-moe-1b-a400m")
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m",
        family="moe",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        head_dim=64,
        d_ff=0,
        vocab_size=49_155,
        rope_theta=10_000.0,
        n_experts=32,
        top_k=8,
        moe_d_ff=512,
        capacity_factor=1.25,
        act="silu",
        tie_embeddings=True,
        remat="full",
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="granite-moe-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, vocab_size=256, n_experts=4, top_k=2,
        moe_d_ff=32, remat="none")
