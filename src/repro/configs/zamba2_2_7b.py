"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention block
[arXiv:2411.15242]."""
from repro.models.config import ModelConfig
from repro.models.registry import register_config


@register_config("zamba2-2.7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        head_dim=80,
        d_ff=10240,
        vocab_size=32_000,
        rope_theta=10_000.0,
        ssm_state=64,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_conv_width=4,
        ssm_chunk=128,
        attn_every=6,  # shared attn+mlp block after every 6th mamba layer
        act="gelu",
        tie_embeddings=True,
        remat="full",
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="zamba2-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256, ssm_state=16,
        ssm_head_dim=16, ssm_chunk=8, attn_every=2, remat="none")
