"""smollm-360m [dense] — llama-arch small model
[hf:HuggingFaceTB/SmolLM-360M]."""
from repro.models.config import ModelConfig
from repro.models.registry import register_config


@register_config("smollm-360m")
def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m",
        family="dense",
        n_layers=32,
        d_model=960,
        n_heads=15,
        n_kv_heads=5,
        head_dim=64,
        d_ff=2560,
        vocab_size=49_152,
        rope_theta=10_000.0,
        act="silu",
        tie_embeddings=True,
        remat="full",
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="smollm-360m-smoke", n_layers=2, d_model=60, n_heads=3,
        n_kv_heads=1, head_dim=20, d_ff=128, vocab_size=256, remat="none")
