"""whisper-medium [audio] — enc-dec, conv frontend stubbed to precomputed
frame embeddings per the assignment [arXiv:2212.04356]."""
from repro.models.config import ModelConfig
from repro.models.registry import register_config


@register_config("whisper-medium")
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        family="audio",
        n_layers=24,           # decoder layers
        n_encoder_layers=24,
        encoder_seq=1500,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,         # MHA
        head_dim=64,
        d_ff=4096,
        vocab_size=51_865,
        qkv_bias=True,
        rope_kind="none",      # learned/sinusoidal positions
        act="gelu",
        tie_embeddings=True,
        remat="full",
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="whisper-medium-smoke", n_layers=2, n_encoder_layers=2,
        encoder_seq=16, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, remat="none")
