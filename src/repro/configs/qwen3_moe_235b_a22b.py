"""qwen3-moe-235b-a22b [moe] — 128 experts top-8, GQA 64Q/4KV, qk-norm
[hf:Qwen/Qwen3-235B-A22B]."""
from repro.models.config import ModelConfig
from repro.models.registry import register_config


@register_config("qwen3-moe-235b-a22b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        head_dim=128,
        d_ff=0,
        vocab_size=151_936,
        qk_norm=True,
        rope_theta=1_000_000.0,
        n_experts=128,
        top_k=8,
        moe_d_ff=1536,
        capacity_factor=1.25,
        act="silu",
        tie_embeddings=False,
        remat="full",
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="qwen3-moe-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, vocab_size=256, n_experts=4, top_k=2,
        moe_d_ff=32, remat="none")
