"""gemma3-1b [dense] — 5:1 local:global sliding-window, 256k vocab
[hf:google/gemma-3-1b-pt]."""
from repro.models.config import ModelConfig
from repro.models.registry import register_config


@register_config("gemma3-1b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b",
        family="dense",
        n_layers=26,
        d_model=1152,
        n_heads=4,
        n_kv_heads=1,
        head_dim=256,
        d_ff=6912,
        vocab_size=262_144,
        qk_norm=True,
        rope_theta=10_000.0,         # local layers
        rope_theta_global=1_000_000.0,  # global layers
        sliding_window=512,
        global_every=6,              # every 6th layer global (5:1)
        act="gelu",
        tie_embeddings=True,
        embed_scale=True,
        remat="full",
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="gemma3-1b-smoke", n_layers=4, d_model=64, n_heads=2,
        n_kv_heads=1, head_dim=32, d_ff=128, vocab_size=256,
        sliding_window=8, global_every=2, remat="none")
