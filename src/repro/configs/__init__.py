"""One module per assigned architecture (exact published config) plus a
``smoke()`` reduced config of the same family for CPU tests."""

CONFIG_MODULES = [
    "qwen2_5_3b",
    "gemma3_1b",
    "minitron_8b",
    "smollm_360m",
    "whisper_medium",
    "qwen2_vl_7b",
    "mamba2_370m",
    "qwen3_moe_235b_a22b",
    "granite_moe_1b_a400m",
    "zamba2_2_7b",
]
