"""mamba2-370m [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060]."""
from repro.models.config import ModelConfig
from repro.models.registry import register_config


@register_config("mamba2-370m")
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        family="ssm",
        n_layers=48,
        d_model=1024,
        n_heads=0,
        n_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=50_280,
        rope_kind="none",
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_conv_width=4,
        ssm_chunk=128,
        tie_embeddings=True,
        remat="full",
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="mamba2-370m-smoke", n_layers=2, d_model=64, vocab_size=256,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=8, remat="none")
