"""minitron-8b [dense] — pruned nemotron, squared-ReLU MLP
[arXiv:2407.14679]."""
from repro.models.config import ModelConfig
from repro.models.registry import register_config


@register_config("minitron-8b")
def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=256_000,
        rope_theta=10_000.0,
        act="relu2",  # nemotron squared-ReLU
        tie_embeddings=False,
        remat="full",
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="minitron-8b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256, remat="none")
