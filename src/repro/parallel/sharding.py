"""Logical-axis sharding (t5x-style axis rules, self-contained).

Model code annotates intermediates and parameters with *logical* axis names
("batch", "heads", "ff", ...). A policy (per arch x shape x mesh) maps the
logical names to physical mesh axes. This indirection is what lets the same
model definition run as DP-only, DP+TP, FSDP+TP+EP, or sequence-parallel
long-context decode without touching the model code — the core requirement
for FlowOS-RM slices whose shape is chosen at *job submission* time.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


class AxisRules:
    """Mapping logical axis name -> physical mesh axis (or tuple, or None)."""

    def __init__(self, rules: Dict[str, MeshAxes], mesh: Optional[Mesh] = None):
        self.rules = dict(rules)
        self.mesh = mesh

    def physical(self, logical: Optional[str]) -> MeshAxes:
        if logical is None:
            return None
        return self.rules.get(logical)

    def spec(self, logical_axes: Sequence[Optional[str]]) -> P:
        used: list = []
        out = []
        for ax in logical_axes:
            phys = self.physical(ax)
            # a mesh axis may be used at most once per spec; later duplicate
            # uses degrade to replication (valid, conservative)
            if phys is None:
                out.append(None)
                continue
            names = (phys,) if isinstance(phys, str) else tuple(phys)
            names = tuple(n for n in names if n not in used)
            used.extend(names)
            if not names:
                out.append(None)
            elif len(names) == 1:
                out.append(names[0])
            else:
                out.append(names)
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def replace(self, **kw) -> "AxisRules":
        r = dict(self.rules)
        r.update(kw)
        return AxisRules(r, self.mesh)


_state = threading.local()


def current_rules() -> Optional[AxisRules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def axis_rules(rules: AxisRules):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Apply a sharding constraint expressed in logical axes.

    No-op when no axis rules are active (single-device smoke tests) or when
    the array rank disagrees (defensive for scan-carried intermediates).
    """
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    if len(logical_axes) != x.ndim:
        return x
    spec = rules.spec(logical_axes)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


def logical_to_spec(rules: AxisRules, axes: Sequence[Optional[str]]) -> P:
    return rules.spec(axes)


def tree_specs(rules: AxisRules, axes_tree):
    """Map a pytree of logical-axis tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda axes: rules.spec(axes),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x),
    )


def sanitize_spec(mesh: Mesh, spec: P, shape) -> P:
    """Drop mesh axes from a PartitionSpec wherever the array dim is not
    divisible by the assigned axes' product (jit in/out shardings must
    divide evenly; internal constraints may pad, boundaries may not)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for d, entry in enumerate(spec):
        if entry is None or d >= len(shape):
            out.append(None if d >= len(shape) else entry)
            continue
        names = (entry,) if isinstance(entry, str) else list(entry)
        names = list(names)
        while names:
            prod = 1
            for n in names:
                prod *= sizes[n]
            if shape[d] % prod == 0:
                break
            names.pop()  # drop the innermost axis and retry
        if not names:
            out.append(None)
        elif len(names) == 1:
            out.append(names[0])
        else:
            out.append(tuple(names))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def sanitize_tree_specs(mesh: Mesh, specs_tree, struct_tree):
    """Apply sanitize_spec leaf-wise (struct_tree supplies shapes)."""
    return jax.tree.map(
        lambda spec, struct: sanitize_spec(mesh, spec, struct.shape),
        specs_tree, struct_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def tree_shardings(rules: AxisRules, axes_tree):
    assert rules.mesh is not None
    return jax.tree.map(
        lambda spec: NamedSharding(rules.mesh, spec),
        tree_specs(rules, axes_tree),
        is_leaf=lambda x: isinstance(x, P),
    )
