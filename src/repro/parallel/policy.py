"""Sharding policy: (arch config, shape, mesh) -> AxisRules.

This is where DP / TP / FSDP / EP / SP are decided. The FlowOS-RM scheduler
calls this when it constructs a slice for a job, so the policy is a function
of the *request* (arch + shape) and the *slice* (mesh), never hard-coded in
model code.

Logical axes used by the models:
  batch      activation batch dim
  seq        activation sequence dim (sharded only for long-context SP)
  act_embed  activation d_model dim (None; Megatron-SP would map it)
  heads      attention q-heads           -> TP when divisible
  kv_heads   attention kv-heads          -> TP when divisible
  kv_seq     KV-cache sequence dim       -> split-KV decode sharding
  ff         MLP hidden                  -> TP
  vocab      vocab dim of embed table / logits -> TP
  embed      param d_model dim           -> FSDP axis
  embed_tbl  embedding-table d_model dim (not FSDP-sharded; gathered often)
  experts    MoE expert dim              -> EP
  expert_ff  per-expert hidden
  ssm_inner  mamba d_inner               -> TP
  ssm_heads  mamba heads                 -> TP
  seq_tbl    positional-embedding table rows
"""
from __future__ import annotations

from typing import Optional

from jax.sharding import Mesh

from repro.models.config import ModelConfig, ShapeConfig
from repro.parallel.sharding import AxisRules


def _divisible(n: int, by: int) -> bool:
    return by > 0 and n > 0 and n % by == 0


def sharding_policy(cfg: ModelConfig, shape: Optional[ShapeConfig],
                    mesh: Mesh, *, fsdp: bool = True,
                    seq_parallel: Optional[bool] = None) -> AxisRules:
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    has_pod = "pod" in axes
    data_axes = ("pod", "data") if has_pod else ("data",)
    n_data = 1
    for a in data_axes:
        n_data *= axes.get(a, 1)
    n_model = axes.get("model", 1)

    batch = shape.global_batch if shape is not None else None
    seq = shape.seq_len if shape is not None else None
    is_decode = shape is not None and shape.is_decode
    long_ctx = shape is not None and shape.name == "long_500k"
    if seq_parallel is None:
        seq_parallel = long_ctx

    rules: dict = {
        "layers": None,
        "act_embed": None,
        "expert_ff": None,
        "seq_tbl": None,
        "embed_tbl": None,
        "seq": None,
        "kv_seq": None,
    }

    # Is tensor parallelism available for this arch? For SSM/hybrid it is
    # the inner/head dims; for attention archs the q-heads.
    if cfg.family in ("ssm", "hybrid"):
        tp_able = _divisible(cfg.ssm_heads, n_model)
    else:
        tp_able = _divisible(cfg.n_heads, n_model)

    n_all = n_data * n_model

    # ---- strategy selection (napkin-math, see DESIGN.md §6) ----
    # TP costs ~16*B_loc*S*d wire bytes per layer (4 ring all-reduces of the
    # activations); FSDP/pure-DP costs ~3x layer-param bytes (gather fwd,
    # re-gather bwd under remat, reduce-scatter grads). At train_4k sizes
    # (64k tokens per device group) activations dwarf per-layer params for
    # every dense arch here, so pure DP wins whenever the batch can fill the
    # whole mesh. MoE archs keep the model axis for EP (expert weights are
    # the one thing that cannot be compute-replicated).
    strategy = "tp"
    if not is_decode:
        if cfg.is_moe:
            strategy = "dp_ep"
        elif batch is not None and _divisible(batch, n_all):
            strategy = "pure_dp"   # model axis joins data parallelism
        elif tp_able:
            strategy = "tp"
        else:
            # Non-TP-able heads with a batch that can't fill the mesh:
            # replicate attention compute over the idle model axis.
            # (seq_tp — sequence over `model` — was measured 16-60x worse
            # on memory: the q/kv block slicing of flash attention crosses
            # shard boundaries and GSPMD falls back to full
            # rematerialization. See EXPERIMENTS.md §Perf iteration 9.)
            strategy = "replicated_attn"

    # ---- data parallel over batch ----
    if strategy == "pure_dp":
        rules["batch"] = data_axes + ("model",)
    elif batch is not None and batch >= n_data and _divisible(batch, n_data):
        rules["batch"] = data_axes if has_pod else "data"
    elif batch is not None and "data" in axes and _divisible(batch, axes["data"]):
        rules["batch"] = "data"
    else:
        rules["batch"] = None  # batch too small (long_500k batch=1)

    # ---- sequence axis ----
    if strategy == "seq_tp":
        rules["seq"] = "model"
    elif seq_parallel and rules["batch"] is None:
        # long-context: shard activations along sequence (ring/SP style)
        rules["seq"] = data_axes if has_pod else "data"

    # ---- tensor parallel (suppressed when the model axis is consumed by
    # pure-DP or EP; seq_tp keeps weight TP only where conflict-free).
    # dp_ep shards attention heads over the model axis too: the expert
    # shard_map only needs tokens replicated over `model` at its boundary,
    # and unsharded attention at B_loc=16 was measured 16x heavier than
    # the whole MoE (EXPERIMENTS.md §Perf iteration 3) ----
    tp_ok = strategy in ("tp", "replicated_attn", "dp_ep")
    rules["heads"] = ("model" if tp_ok and _divisible(cfg.n_heads, n_model)
                      else None)
    rules["kv_heads"] = ("model"
                         if tp_ok and _divisible(cfg.n_kv_heads, n_model)
                         else None)
    rules["ff"] = ("model" if tp_ok and _divisible(cfg.d_ff, n_model)
                   else None)
    rules["vocab"] = "model" if strategy != "pure_dp" else None
    rules["ssm_inner"] = ("model"
                          if tp_ok and _divisible(cfg.d_inner, n_model)
                          else None)
    rules["ssm_heads"] = ("model"
                          if tp_ok and _divisible(cfg.ssm_heads, n_model)
                          else None)

    # ---- expert parallel ----
    rules["experts"] = ("model"
                        if strategy in ("tp", "replicated_attn", "dp_ep")
                        and _divisible(cfg.n_experts, n_model)
                        else None)

    # ---- LM-head sequence sharding (Megatron-SP-style loss) ----
    rules["seq_ce"] = ("model" if strategy not in ("pure_dp",) else None)

    # ---- sequence-parallel attention (shard_map): non-TP-able archs with
    # the model axis otherwise idle for attention ----
    rules["attn_sp"] = ("model" if strategy == "replicated_attn"
                        and seq is not None and _divisible(seq, n_model * 512)
                        else None)

    # ---- KV-cache sharding for decode ----
    if is_decode:
        if rules["batch"] is None:
            # batch=1 long-context: split KV over every axis we have
            rules["kv_seq"] = (data_axes + ("model",) if has_pod
                               else ("data", "model"))
        elif rules["kv_heads"] is not None:
            rules["kv_seq"] = None  # heads give enough parallelism
        else:
            rules["kv_seq"] = "model"  # flash-decode split-KV

    # ---- FSDP for parameters ----
    if fsdp:
        rules["embed"] = (("data", "model") if strategy == "pure_dp"
                          else "data")
    else:
        rules["embed"] = None

    r = AxisRules(rules, mesh)
    r.strategy = strategy
    return r
