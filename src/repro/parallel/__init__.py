from repro.parallel.sharding import (  # noqa: F401
    AxisRules,
    axis_rules,
    current_rules,
    logical_to_spec,
    shard,
    tree_specs,
)
from repro.parallel.policy import sharding_policy  # noqa: F401
