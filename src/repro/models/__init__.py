from repro.models.config import ModelConfig, ShapeConfig, SHAPES  # noqa: F401
from repro.models.registry import get_model, get_config, list_architectures  # noqa: F401
