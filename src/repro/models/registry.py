"""Architecture registry: ``--arch <id>`` resolves here.

Each entry binds a ``ModelConfig`` to the family implementation
(init / apply / init_cache / decode_step / param_axes / cache_axes).
"""
from __future__ import annotations

import dataclasses
import functools
import importlib
from typing import Any, Callable, Dict, Optional

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[..., Any]
    apply: Callable[..., Any]
    apply_hidden: Callable[..., Any]
    param_axes: Callable[..., Any]
    init_cache: Optional[Callable[..., Any]] = None
    decode_step: Optional[Callable[..., Any]] = None
    cache_axes: Optional[Callable[..., Any]] = None


_FAMILY_MODULE = {
    "dense": "repro.models.transformer",
    "moe": "repro.models.transformer",
    "vlm": "repro.models.transformer",
    "ssm": "repro.models.mamba2",
    "hybrid": "repro.models.hybrid",
    "audio": "repro.models.whisper",
}

_CONFIGS: Dict[str, Callable[[], ModelConfig]] = {}


def register_config(name: str):
    def deco(fn):
        _CONFIGS[name] = fn
        return fn
    return deco


def _load_configs():
    if _CONFIGS:
        return
    from repro import configs as cfg_pkg  # noqa: F401
    for mod in cfg_pkg.CONFIG_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


def list_architectures():
    _load_configs()
    return sorted(_CONFIGS)


def get_config(name: str, **overrides) -> ModelConfig:
    _load_configs()
    if name not in _CONFIGS:
        raise KeyError(f"unknown arch {name!r}; known: {list_architectures()}")
    cfg = _CONFIGS[name]()
    return cfg.replace(**overrides) if overrides else cfg


def get_model(name_or_cfg, **overrides) -> Model:
    cfg = (name_or_cfg if isinstance(name_or_cfg, ModelConfig)
           else get_config(name_or_cfg, **overrides))
    mod = importlib.import_module(_FAMILY_MODULE[cfg.family])
    init = mod.init
    if cfg.family == "audio":
        init = functools.partial(mod.init, max_target_len=32_768)
    return Model(
        cfg=cfg,
        init=init,
        apply=mod.apply,
        apply_hidden=mod.apply_hidden,
        param_axes=mod.param_axes,
        init_cache=getattr(mod, "init_cache", None),
        decode_step=getattr(mod, "decode_step", None),
        cache_axes=getattr(mod, "cache_axes", None),
    )
