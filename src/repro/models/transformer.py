"""Decoder-only transformer LM covering the dense, MoE and VLM families.

Variants are driven entirely by ``ModelConfig``:
  * GQA with optional QKV bias / qk-norm / logit softcap
  * RoPE (standard, dual-theta local/global for gemma3, M-RoPE for qwen2-vl)
  * sliding-window attention with a per-layer local/global pattern (gemma3)
  * MoE FFN (expert-parallel, see layers.moe)
  * vision-patch stub inputs (qwen2-vl backbone; frontend per assignment)

Layer parameters are stacked along a leading layer axis and consumed with
``jax.lax.scan`` so HLO size is O(1) in depth (the 94-layer MoE compiles
fast). Archs with a local:global pattern (gemma3 5:1) use a *segmented*
scan — one scan over segments with the pattern unrolled inside — so local
layers statically use banded sliding-window attention (true O(S*w) compute)
and global layers full attention, with no wasted branch.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.parallel.sharding import shard


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    p = {
        "ln1": L.init_rmsnorm(cfg.d_model),
        "attn": L.init_attention(ks[0], cfg),
        "ln2": L.init_rmsnorm(cfg.d_model),
    }
    if cfg.is_moe:
        p["moe"] = L.init_moe(ks[1], cfg)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg)
    return p


def _layer_axes(cfg: ModelConfig):
    ax = {
        "ln1": L.rmsnorm_axes(),
        "attn": L.attention_axes(cfg),
        "ln2": L.rmsnorm_axes(),
    }
    if cfg.is_moe:
        ax["moe"] = L.moe_axes()
    else:
        ax["mlp"] = L.mlp_axes(cfg)
    return ax


def init(cfg: ModelConfig, key) -> dict:
    k_embed, k_layers = jax.random.split(key, 2)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: _init_layer(k, cfg))(layer_keys)
    return {
        "embed": L.init_embed(k_embed, cfg),
        "layers": stacked,
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }


def param_axes(cfg: ModelConfig):
    """Logical axis names mirroring ``init`` (leading layer axis on stacks)."""
    stack = jax.tree.map(lambda axes: (None,) + axes, _layer_axes(cfg),
                         is_leaf=lambda x: isinstance(x, tuple))
    return {
        "embed": L.embed_axes(cfg),
        "layers": stack,
        "final_norm": L.rmsnorm_axes(),
    }


# ---------------------------------------------------------------------------
# rope helpers
# ---------------------------------------------------------------------------

def mrope_positions(cfg: ModelConfig, S: int) -> jax.Array:
    """(3, S) — temporal/height/width positions: a vision patch grid at the
    start of the sequence (stub frontend), text after it."""
    P = min(cfg.n_vision_patches, S)
    grid_w = max(int(P ** 0.5), 1)
    i = jnp.arange(S)
    in_img = i < P
    t = jnp.where(in_img, 0, i - P + 1)
    h = jnp.where(in_img, i // grid_w, i - P + 1)
    w = jnp.where(in_img, i % grid_w, i - P + 1)
    return jnp.stack([t, h, w]).astype(jnp.int32)


def _angles_for(cfg: ModelConfig, positions):
    """(angles_local, angles_global) for the given positions."""
    if cfg.rope_kind == "none":
        return None, None
    sections = cfg.mrope_sections if cfg.rope_kind == "mrope" else ()
    a_local = L.rope_angles(positions, cfg.head_dim, cfg.rope_theta, sections)
    if cfg.rope_theta_global:
        a_global = L.rope_angles(positions, cfg.head_dim,
                                 cfg.rope_theta_global, sections)
    else:
        a_global = a_local
    return a_local, a_global


def _positions_for(cfg: ModelConfig, B: int, S: int):
    if cfg.family == "vlm":
        return mrope_positions(cfg, S)[:, None, :].repeat(B, 1)
    return jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)


def _layer_pattern(cfg: ModelConfig):
    """List of per-layer window values (None = global/full attention)."""
    if cfg.global_every:
        return [None if (i + 1) % cfg.global_every == 0 else cfg.sliding_window
                for i in range(cfg.n_layers)]
    return [cfg.sliding_window] * cfg.n_layers


# ---------------------------------------------------------------------------
# forward (full-sequence: train / prefill)
# ---------------------------------------------------------------------------

def _layer_fwd(cfg: ModelConfig, window: Optional[int], x, p, angles):
    a_in = L.rmsnorm(p["ln1"], x, cfg.norm_eps, use_pallas=cfg.use_pallas)
    attn = L.attention(p["attn"], cfg, a_in, angles=angles, causal=True,
                       window=window, softcap=cfg.logit_softcap)
    x = x + attn
    x = shard(x, "batch", "seq", "act_embed")
    m_in = L.rmsnorm(p["ln2"], x, cfg.norm_eps, use_pallas=cfg.use_pallas)
    aux = jnp.zeros((), jnp.float32)
    if cfg.is_moe:
        m_out, aux = L.moe(p["moe"], cfg, m_in)
    else:
        m_out = L.mlp(p["mlp"], cfg, m_in)
    x = x + m_out
    x = shard(x, "batch", "seq", "act_embed")
    return x, aux


def _remat(cfg: ModelConfig, fn):
    if cfg.remat in ("full", "sqrt"):  # sqrt: layer remat inside the
        return jax.checkpoint(fn)      # checkpointed group scan
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def _segments(cfg: ModelConfig):
    """(segment_len, n_segments, tail) for the pattern-scan layout."""
    if not cfg.global_every:
        return 1, cfg.n_layers, 0
    seg = cfg.global_every
    n_seg = cfg.n_layers // seg
    return seg, n_seg, cfg.n_layers - seg * n_seg


def _sqrt_factor(n: int) -> int:
    """Largest divisor of n that is <= sqrt(n)."""
    best = 1
    f = 1
    while f * f <= n:
        if n % f == 0:
            best = f
        f += 1
    return best


def _scan_layers(cfg: ModelConfig, x, stacked, step_fn):
    """Run all layers via segmented scan. ``step_fn(x, p, window, li)`` is
    called per layer (li = index within segment) and must return (x, aux).

    remat == "sqrt" (uniform stacks only): sqrt-checkpointing — an outer
    scan over ~sqrt(L) checkpointed groups of an inner scan, so the AD
    residual stack holds O(sqrt(L)) layer inputs instead of O(L)
    (EXPERIMENTS.md §Perf iteration 8: the 94-layer MoE's 12.6 GB of
    carried layer inputs)."""
    pattern = _layer_pattern(cfg)
    seg, n_seg, tail = _segments(cfg)

    if cfg.remat == "sqrt" and seg == 1 and tail == 0:
        n_in = _sqrt_factor(cfg.n_layers)
        n_out = cfg.n_layers // n_in
        grouped = jax.tree.map(
            lambda a: a.reshape((n_out, n_in) + a.shape[1:]), stacked)

        @jax.checkpoint
        def group_body(carry, p_grp):
            def inner(c, p):
                x, aux = c
                x, a = step_fn(x, p, pattern[0], 0)
                return (x, aux + a), None
            c, _ = jax.lax.scan(inner, carry, p_grp)
            return c, None

        (x, aux), _ = jax.lax.scan(group_body,
                                   (x, jnp.zeros((), jnp.float32)), grouped)
        return x, aux

    body_params = jax.tree.map(
        lambda a: a[: seg * n_seg].reshape((n_seg, seg) + a.shape[1:]),
        stacked)

    def seg_body(carry, p_seg):
        x, aux = carry
        for j in range(seg):
            p_j = jax.tree.map(lambda a: a[j], p_seg)
            x, a = step_fn(x, p_j, pattern[j], j)
            aux = aux + a
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(seg_body, (x, jnp.zeros((), jnp.float32)),
                               body_params)
    for t in range(tail):
        li = seg * n_seg + t
        p_t = jax.tree.map(lambda a: a[li], stacked)
        x, a = step_fn(x, p_t, pattern[li], 0)
        aux = aux + a
    return x, aux


def apply_hidden(cfg: ModelConfig, params, batch):
    """Full-sequence forward to final hidden states. batch: {"tokens":
    (B, S) int32, optional "vision_embeds": (B, P, d)}.
    Returns (hidden (B, S, d), aux_loss)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed(params["embed"], cfg, tokens)
    if cfg.family == "vlm" and batch.get("vision_embeds") is not None:
        P = min(cfg.n_vision_patches, S)
        ve = batch["vision_embeds"].astype(x.dtype)[:, :P]
        x = jnp.concatenate([ve, x[:, P:]], axis=1)
    x = shard(x, "batch", "seq", "act_embed")
    angles_l, angles_g = _angles_for(cfg, _positions_for(cfg, B, S))

    def step(x, p, window, _li):
        angles = angles_g if window is None else angles_l
        return _remat(cfg, functools.partial(_layer_fwd, cfg, window))(
            x, p, angles)

    x, aux = _scan_layers(cfg, x, params["layers"], step)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps,
                  use_pallas=cfg.use_pallas)
    return x, aux


def apply(cfg: ModelConfig, params, batch):
    """Returns (logits (B, S, V), aux_loss)."""
    x, aux = apply_hidden(cfg, params, batch)
    logits = L.unembed(params["embed"], cfg, x)
    return logits, aux


# ---------------------------------------------------------------------------
# decode (single token against a KV cache)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    return L.init_kv_cache(cfg, batch, max_len, cfg.n_layers, dtype)


def cache_axes(cfg: ModelConfig):
    return L.kv_cache_axes()


def decode_step(cfg: ModelConfig, params, cache, tokens):
    """tokens: (B, 1). Returns (logits (B, 1, V), new_cache)."""
    B = tokens.shape[0]
    x = L.embed(params["embed"], cfg, tokens)
    x = shard(x, "batch", "seq", "act_embed")
    idx = cache["len"][0, 0]  # uniform absolute decode position
    if cfg.family == "vlm":
        # same (t, h, w) mapping as mrope_positions for a single index
        P = min(cfg.n_vision_patches, 10 ** 9)
        gw = max(int(P ** 0.5), 1)
        txt = idx - P + 1
        t = jnp.where(idx < P, 0, txt)
        h = jnp.where(idx < P, idx // gw, txt)
        w = jnp.where(idx < P, idx % gw, txt)
        pos = jnp.stack([t, h, w]).reshape(3, 1, 1)
        pos = jnp.broadcast_to(pos, (3, B, 1)).astype(jnp.int32)
    else:
        pos = jnp.broadcast_to(idx, (B, 1)).astype(jnp.int32)
    angles_l, angles_g = _angles_for(cfg, pos)

    pattern = _layer_pattern(cfg)
    seg, n_seg, tail = _segments(cfg)
    body_in = jax.tree.map(
        lambda a: a[: seg * n_seg].reshape((n_seg, seg) + a.shape[1:]),
        (params["layers"], cache))

    def one_layer(x, p, layer_cache, window):
        angles = angles_g if window is None else angles_l
        a_in = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        attn, new_cache = L.attention_decode(
            p["attn"], cfg, a_in, layer_cache, angles=angles, window=window,
            softcap=cfg.logit_softcap)
        x = x + attn
        m_in = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if cfg.is_moe:
            m_out, _ = L.moe(p["moe"], cfg, m_in)
        else:
            m_out = L.mlp(p["mlp"], cfg, m_in)
        return x + m_out, new_cache

    def seg_body(x, scanned):
        p_seg, c_seg = scanned
        new_cs = []
        for j in range(seg):
            p_j = jax.tree.map(lambda a: a[j], p_seg)
            c_j = jax.tree.map(lambda a: a[j], c_seg)
            x, nc = one_layer(x, p_j, c_j, pattern[j])
            new_cs.append(nc)
        stacked_c = jax.tree.map(lambda *xs: jnp.stack(xs), *new_cs)
        return x, stacked_c

    x, new_cache_body = jax.lax.scan(seg_body, x, body_in)
    new_cache_body = jax.tree.map(
        lambda a: a.reshape((seg * n_seg,) + a.shape[2:]), new_cache_body)
    tail_caches = []
    for t in range(tail):
        li = seg * n_seg + t
        p_t = jax.tree.map(lambda a: a[li], params["layers"])
        c_t = jax.tree.map(lambda a: a[li], cache)
        x, nc = one_layer(x, p_t, c_t, pattern[li])
        tail_caches.append(nc)
    if tail_caches:
        tail_stack = jax.tree.map(lambda *xs: jnp.stack(xs), *tail_caches)
        new_cache = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], axis=0),
            new_cache_body, tail_stack)
    else:
        new_cache = new_cache_body
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], cfg, x)
    return logits, new_cache
