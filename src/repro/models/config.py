"""Model configuration for the architecture zoo.

Every assigned architecture is expressed as a ``ModelConfig``. The config is
a frozen dataclass so it can be used as a static argument to ``jax.jit``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention ---
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_theta_global: Optional[float] = None  # gemma3: global layers use 1M
    rope_kind: str = "standard"  # standard | mrope | none | learned
    mrope_sections: Tuple[int, ...] = ()  # qwen2-vl: freq-dim split (t,h,w)
    sliding_window: Optional[int] = None
    global_every: Optional[int] = None  # every Nth layer is global (gemma3: 6)
    logit_softcap: Optional[float] = None

    # --- mlp ---
    act: str = "silu"  # silu | gelu | relu2

    # --- moe ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- ssm (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    ssm_n_groups: int = 1

    # --- hybrid (zamba2) ---
    attn_every: int = 0  # shared attention block after every k-th ssm layer

    # --- encoder-decoder (whisper) ---
    n_encoder_layers: int = 0
    encoder_seq: int = 0  # fixed encoder frame count (whisper: 1500)

    # --- vlm ---
    n_vision_patches: int = 0  # stub patch-embedding count folded into seq

    # --- norm / embeddings ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    embed_scale: bool = False  # gemma-style sqrt(d_model) embedding scale

    # --- numerics / implementation ---
    dtype: str = "bfloat16"
    remat: str = "none"  # none | dots | full
    use_pallas: bool = False  # pallas kernels (TPU); jnp path used for dry-run
    attn_stub: bool = False  # perf analysis: elide the attention core so
    # the kernel-substitution tool can measure non-attention traffic

    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_ssm(self) -> bool:
        return self.family == "ssm"

    @property
    def is_hybrid(self) -> bool:
        return self.family == "hybrid"

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports long-context (500k) decode per spec:
        SSM / hybrid / sliding-window-local attention families."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    @property
    def has_decoder(self) -> bool:
        """Encoder-only archs have no decode step (all assigned archs decode)."""
        return True

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Approximate parameter count (embedding + layers), for roofline's
        MODEL_FLOPS = 6*N*D."""
        d, hd = self.d_model, self.head_dim
        n = 0
        n += self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d
        if self.family in ("ssm", "hybrid"):
            di, ds, nh = self.d_inner, self.ssm_state, self.ssm_heads
            g = self.ssm_n_groups
            proj_in = d * (2 * di + 2 * g * ds + nh)
            conv = (di + 2 * g * ds) * self.ssm_conv_width
            proj_out = di * d
            per_layer = proj_in + conv + proj_out + 2 * nh + di + d
            n += self.n_layers * per_layer
            if self.is_hybrid and self.attn_every:
                # one shared attention+mlp block
                n += (2 * d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                      + 3 * d * self.d_ff + 2 * d)
            return n
        attn = (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                + self.n_heads * hd * d)
        if self.is_moe:
            ffn = self.n_experts * 3 * d * self.moe_d_ff + d * self.n_experts
        else:
            ffn = 3 * d * self.d_ff if self.act != "relu2" else 2 * d * self.d_ff
        per_layer = attn + ffn + 2 * d
        n += self.n_layers * per_layer
        if self.is_encdec:
            # encoder layers + decoder cross-attention
            enc = self.n_encoder_layers * (attn + ffn + 2 * d)
            cross = self.n_layers * (attn + d)
            n += enc + cross
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.n_layers * self.n_experts * 3 * d * self.moe_d_ff
        active_ffn = self.n_layers * self.top_k * 3 * d * self.moe_d_ff
        return dense + active_ffn


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
