"""Layer primitives shared by the architecture zoo.

Pure-functional JAX; parameters are plain dict pytrees. Every intermediate is
annotated with *logical* sharding axes via ``repro.parallel.shard`` so the
same code serves single-device smoke tests and 512-chip pjit dry-runs.

Each primitive has an ``init_*`` (params), ``*_axes`` (logical axis names for
the param pytree — consumed by the sharding policy), and an apply function.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.parallel.sharding import shard


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def _dense_init(key, shape, in_axis_size, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(max(in_axis_size, 1))
    return jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype) * scale


def _embed_init(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * 0.02


def compute_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm_axes():
    return {"scale": ("embed",)}


def rmsnorm(params, x, eps: float, *, use_pallas: bool = False):
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.rmsnorm(x, params["scale"], eps=eps)
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def qk_head_norm(scale, x, eps: float):
    """Per-head RMSNorm over head_dim (gemma3 / qwen3 qk-norm)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_inv_freq(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def rope_angles(positions: jax.Array, head_dim: int, theta: float,
                sections: Tuple[int, ...] = ()) -> jax.Array:
    """positions: (..., S) int32 for standard; (3, ..., S) for M-RoPE.

    Returns angles (..., S, head_dim//2) float32.
    """
    inv = rope_inv_freq(head_dim, theta)  # (hd/2,)
    if sections:
        # M-RoPE (Qwen2-VL): the frequency dim is split into len(sections)
        # groups; group g uses positions[g] (temporal / height / width).
        assert positions.ndim >= 2 and positions.shape[0] == len(sections)
        angles = positions[..., None].astype(jnp.float32) * inv  # (3,...,S,hd/2)
        parts = []
        off = 0
        for g, width in enumerate(sections):
            parts.append(angles[g, ..., off:off + width])
            off += width
        return jnp.concatenate(parts, axis=-1)
    return positions[..., None].astype(jnp.float32) * inv


def apply_rope(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x: (B, S, H, hd); angles: (B, S, hd/2) or (S, hd/2).

    NeoX-style rotate-half (matches Llama/Qwen/Gemma HF implementations).
    """
    dt = x.dtype
    half = x.shape[-1] // 2
    cos = jnp.cos(angles)[..., None, :]  # (B,S,1,hd/2)
    sin = jnp.sin(angles)[..., None, :]
    if angles.ndim == 2:
        cos, sin = cos[None], sin[None]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# Attention (GQA, causal / sliding-window / cross, train + decode)
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, hq, hd), d),
        "wk": _dense_init(ks[1], (d, hkv, hd), d),
        "wv": _dense_init(ks[2], (d, hkv, hd), d),
        "wo": _dense_init(ks[3], (hq, hd, d), hq * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq, hd), jnp.float32)
        p["bk"] = jnp.zeros((hkv, hd), jnp.float32)
        p["bv"] = jnp.zeros((hkv, hd), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def attention_axes(cfg: ModelConfig):
    ax = {
        "wq": ("embed", "heads", None),
        "wk": ("embed", "kv_heads", None),
        "wv": ("embed", "kv_heads", None),
        "wo": ("heads", None, "embed"),
    }
    if cfg.qkv_bias:
        ax["bq"] = ("heads", None)
        ax["bk"] = ("kv_heads", None)
        ax["bv"] = ("kv_heads", None)
    if cfg.qk_norm:
        ax["q_norm"] = (None,)
        ax["k_norm"] = (None,)
    return ax


def _project_qkv(params, x, cfg: ModelConfig):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    if cfg.qk_norm:
        q = qk_head_norm(params["q_norm"], q, cfg.norm_eps)
        k = qk_head_norm(params["k_norm"], k, cfg.norm_eps)
    return q, k, v


def mha_core(q, k, v, *, causal: bool, window: Optional[int],
             q_positions: Optional[jax.Array] = None,
             kv_positions: Optional[jax.Array] = None,
             kv_len: Optional[jax.Array] = None,
             softcap: Optional[float] = None,
             scale: Optional[float] = None) -> jax.Array:
    """Grouped-query attention core, fp32 softmax.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D). Hq % Hkv == 0.
    q_positions/kv_positions: 1D (Sq,)/(Skv,) absolute positions shared
    across the batch; kv_len: (B,) masks the cache tail in decode.

    Masking is a compact *additive* (Sq, Skv) fp32 term — building a
    broadcast boolean mask at the grouped-head score shape makes XLA hoist
    a full (B,Hkv,G,Sq,Skv) invariant out of the layer scan (gigabytes of
    loop-carried traffic; observed before this was rewritten).
    """
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    qg = q.reshape(B, Sq, Hkv, G, D)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
    if softcap is not None:
        scores = jnp.tanh(scores / softcap) * softcap

    if q_positions is None:
        q_positions = jnp.arange(Sq, dtype=jnp.int32)
    if kv_positions is None:
        kv_positions = jnp.arange(Skv, dtype=jnp.int32)
    qp = q_positions[:, None]   # (Sq, 1)
    kp = kv_positions[None, :]  # (1, Skv)
    valid = jnp.ones((Sq, Skv), bool)
    if causal:
        valid &= kp <= qp
    if window is not None:
        valid &= (qp - kp) < window
    addmask = jnp.where(valid, 0.0, -1e30).astype(jnp.float32)
    scores = scores + addmask  # broadcast over (B, Hkv, G)
    if kv_len is not None:
        tail = jnp.where(kv_positions[None, :] < kv_len[:, None], 0.0, -1e30)
        scores = scores + tail[:, None, None, None, :].astype(jnp.float32)

    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, Sq, Hq, D)


def flash_mha(q, k, v, *, causal: bool = True,
              softcap: Optional[float] = None,
              scale: Optional[float] = None,
              bq: int = 512, bkv: int = 1024,
              q_offset=0) -> jax.Array:
    """Flash-style attention in pure JAX: q-block x kv-block tiling with an
    online softmax, kv-scan body checkpointed so neither forward nor
    backward ever materializes an (Sq, Skv) score tensor to HBM. This is
    the jnp twin of kernels/flash_attention.py and is what the dry-run
    lowers (Pallas cannot lower on the CPU backend) — without it the
    roofline memory term is dominated by score traffic that would not
    exist on the real deployment.
    """
    B, S, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    if S % bq != 0 or Skv % bkv != 0:
        return mha_core(q, k, v, causal=causal, window=None, softcap=softcap,
                        scale=scale)
    nq, nkv = S // bq, Skv // bkv
    qg = q.reshape(B, S, Hkv, G, D)
    kc = k.reshape(B, nkv, bkv, Hkv, D)
    vc = v.reshape(B, nkv, bkv, Hkv, D)

    def one_q_block(i):
        qb = jax.lax.dynamic_slice_in_dim(qg, i * bq, bq, axis=1)
        q_pos = q_offset + i * bq + jnp.arange(bq)

        @jax.checkpoint
        def kv_step(carry, inp):
            m, lsum, acc = carry
            kb, vb, j = inp  # (B,bkv,Hkv,D), (B,bkv,Hkv,D), ()
            s = jnp.einsum("bskgd,btkd->bkgst", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            if softcap is not None:
                s = jnp.tanh(s / softcap) * softcap
            if causal:
                kv_pos = j * bkv + jnp.arange(bkv)
                mask = q_pos[:, None] >= kv_pos[None, :]
                s = s + jnp.where(mask, 0.0, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            lsum = lsum * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgst,btkd->bkgsd", p.astype(q.dtype), vb,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, lsum, acc), None

        m0 = jnp.full((B, Hkv, G, bq), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, bq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, bq, D), jnp.float32)
        if causal:
            # blocks with j >= n_valid are fully masked for this q block
            n_valid = jnp.minimum(
                (q_offset + (i + 1) * bq + bkv - 1) // bkv, nkv)
        else:
            n_valid = nkv
        ks_ = kc.transpose(1, 0, 2, 3, 4)
        vs_ = vc.transpose(1, 0, 2, 3, 4)

        def body(carry, inp):
            kb, vb, j = inp
            new_carry, _ = kv_step(carry, (kb, vb, j))
            if causal:
                skip = j >= n_valid
                new_carry = jax.tree.map(
                    lambda old, new: jnp.where(skip, old, new), carry,
                    new_carry)
            return new_carry, None

        (m, lsum, acc), _ = jax.lax.scan(
            body, (m0, l0, a0), (ks_, vs_, jnp.arange(nkv)))
        out = acc / jnp.maximum(lsum, 1e-30)[..., None]
        return out.astype(q.dtype)  # (B, Hkv, G, bq, D)

    blocks = jax.lax.map(one_q_block, jnp.arange(nq))  # (nq,B,Hkv,G,bq,D)
    out = blocks.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, Hq, D)
    return out


def chunked_mha(q, k, v, *, causal: bool, window: Optional[int],
                softcap: Optional[float] = None, chunk: int = 2048):
    """Q-chunked attention: never materializes the full (Sq, Skv) score
    matrix. For sliding-window layers only a static KV band per q-chunk is
    read, making local attention truly O(S * window) — this is what lets
    gemma3 run the 500k-context cells.
    """
    B, S, H, D = q.shape
    if S % chunk != 0:
        return mha_core(q, k, v, causal=causal, window=window,
                        softcap=softcap)
    nq = S // chunk

    if window is not None and window < S:
        band = min(chunk + window, S)

        def body(i):
            q0 = i * chunk
            qc = jax.lax.dynamic_slice_in_dim(q, q0, chunk, axis=1)
            k0 = jnp.clip(q0 + chunk - band, 0, S - band)
            kc = jax.lax.dynamic_slice_in_dim(k, k0, band, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, k0, band, axis=1)
            qp = q0 + jnp.arange(chunk, dtype=jnp.int32)
            kp = k0 + jnp.arange(band, dtype=jnp.int32)
            return mha_core(qc, kc, vc, causal=causal, window=window,
                            q_positions=qp, kv_positions=kp, softcap=softcap)
    else:
        def body(i):
            q0 = i * chunk
            qc = jax.lax.dynamic_slice_in_dim(q, q0, chunk, axis=1)
            qp = q0 + jnp.arange(chunk, dtype=jnp.int32)
            return mha_core(qc, k, v, causal=causal, window=window,
                            q_positions=qp, kv_positions=None,
                            softcap=softcap)

    outs = jax.lax.map(body, jnp.arange(nq))  # (nq, B, chunk, H, D)
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D)


_CHUNK_THRESHOLD = 4096


def sp_flash_attention(q, k, v, *, causal: bool, softcap, seq_axis: str,
                       batch_axis):
    """Sequence-parallel attention: the q rows are sharded over
    ``seq_axis`` (each shard computes S/n rows against the all-gathered
    KV) — proper compute sharding for archs whose head count does not
    divide the model axis (replicating attention burns 16x compute;
    sharding the sequence via plain constraints makes GSPMD fully
    rematerialize the flash block slices)."""
    from jax import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.parallel.sharding import current_rules

    rules = current_rules()
    mesh = rules.mesh
    spec = P(batch_axis, seq_axis, None, None)

    def local(q_l, k_l, v_l):
        k_full = jax.lax.all_gather(k_l, seq_axis, axis=1, tiled=True)
        v_full = jax.lax.all_gather(v_l, seq_axis, axis=1, tiled=True)
        S_loc = q_l.shape[1]
        off = jax.lax.axis_index(seq_axis) * S_loc
        return flash_mha(q_l, k_full, v_full, causal=causal,
                         softcap=softcap, q_offset=off,
                         bq=min(512, S_loc))

    return shard_map(local, mesh=mesh, in_specs=(spec, spec, spec),
                     out_specs=spec, check_vma=False)(q, k, v)


def attention(params, cfg: ModelConfig, x, *, angles=None, causal=True,
              window: Optional[int] = None, kv_x=None, softcap=None):
    """Self (or cross, via kv_x) attention for full-sequence passes."""
    dt = x.dtype
    q, k, v = (None, None, None)
    if kv_x is None:
        q, k, v = _project_qkv(params, x, cfg)
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
        k = jnp.einsum("bsd,dhk->bshk", kv_x, params["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", kv_x, params["wv"].astype(dt))
        if cfg.qkv_bias:
            q = q + params["bq"].astype(dt)
            k = k + params["bk"].astype(dt)
            v = v + params["bv"].astype(dt)
    if angles is not None and kv_x is None:
        q = apply_rope(q, angles)
        k = apply_rope(k, angles)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)

    S = q.shape[1]
    from repro.parallel.sharding import current_rules
    _rules = current_rules()
    sp_axis = _rules.physical("attn_sp") if _rules is not None else None
    if cfg.attn_stub:
        # kernel-substitution analysis: the attention core is replaced by
        # a zero map (projections kept live) so core HLO traffic can be
        # measured by difference
        out = (q + (jnp.mean(k) + jnp.mean(v)) * 0).astype(q.dtype)
    elif cfg.use_pallas and kv_x is None:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=causal, window=window,
                                   softcap=softcap)
    elif (sp_axis is not None and kv_x is None and causal
          and window is None and S >= 8192):
        out = sp_flash_attention(q, k, v, causal=True, softcap=softcap,
                                 seq_axis=sp_axis,
                                 batch_axis=_rules.physical("batch"))
    elif (kv_x is None and window is not None and window < S
          and S > _CHUNK_THRESHOLD):
        # sliding-window layers: static KV band per q-chunk (O(S*w))
        out = chunked_mha(q, k, v, causal=causal, window=window,
                          softcap=softcap)
    elif kv_x is None and causal and window is None and S >= 1024:
        # full causal attention: flash-style online softmax (no (S,S)
        # score tensor ever reaches HBM)
        out = flash_mha(q, k, v, causal=True, softcap=softcap)
    else:
        out = mha_core(q, k, v, causal=causal and kv_x is None,
                       window=window, softcap=softcap)
    out = shard(out, "batch", "seq", "heads", None)
    return jnp.einsum("bshd,hdo->bso", out, params["wo"].astype(dt))


def attention_decode(params, cfg: ModelConfig, x, cache, *, angles=None,
                     window: Optional[int] = None, softcap=None):
    """Single-token decode with a KV cache.

    x: (B, 1, d). cache: {"k": (B, S_max, Hkv, D), "v": ..., "len": (B,)}.
    Returns (out (B,1,d), new_cache).
    """
    dt = x.dtype
    q, k_new, v_new = _project_qkv(params, x, cfg)
    if angles is not None:
        q = apply_rope(q, angles)
        k_new = apply_rope(k_new, angles)

    idx = cache["len"][0]  # uniform decode position across batch
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), idx, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), idx, axis=1)
    k = shard(k, "batch", "kv_seq", "kv_heads", None)
    v = shard(v, "batch", "kv_seq", "kv_heads", None)

    B = x.shape[0]
    q_pos = jnp.full((1,), idx, jnp.int32)
    kv_len = jnp.full((B,), idx + 1, jnp.int32)
    if cfg.use_pallas:
        from repro.kernels import ops as kops
        out = kops.decode_attention(q, k.astype(dt), v.astype(dt),
                                    kv_len=kv_len, window=window,
                                    q_pos=q_pos, softcap=softcap)
    else:
        out = mha_core(q, k.astype(dt), v.astype(dt), causal=True,
                       window=window, q_positions=q_pos,
                       kv_positions=None, kv_len=kv_len, softcap=softcap)
    out = jnp.einsum("bshd,hdo->bso", out, params["wo"].astype(dt))
    new_cache = {"k": k, "v": v, "len": cache["len"] + 1}
    return out, new_cache


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int,
                  dtype=jnp.bfloat16, window: Optional[int] = None):
    """Stacked (per-layer) KV cache. Sliding-window layers allocate only
    the window (gemma3 long-context decode feasibility)."""
    s = min(max_len, window) if window else max_len
    return {
        "k": jnp.zeros((n_layers, batch, s, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((n_layers, batch, s, cfg.n_kv_heads, cfg.head_dim), dtype),
        "len": jnp.zeros((n_layers, batch), jnp.int32),
    }


def kv_cache_axes():
    return {"k": (None, "batch", "kv_seq", "kv_heads", None),
            "v": (None, "batch", "kv_seq", "kv_heads", None),
            "len": (None, "batch")}


# ---------------------------------------------------------------------------
# MLP (gated silu / gelu / relu^2)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_model: Optional[int] = None,
             d_ff: Optional[int] = None):
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "relu2":  # nemotron/minitron: no gate
        return {"wu": _dense_init(ks[0], (d, f), d),
                "wd": _dense_init(ks[1], (f, d), f)}
    return {"wg": _dense_init(ks[0], (d, f), d),
            "wu": _dense_init(ks[1], (d, f), d),
            "wd": _dense_init(ks[2], (f, d), f)}


def mlp_axes(cfg: ModelConfig):
    if cfg.act == "relu2":
        return {"wu": ("embed", "ff"), "wd": ("ff", "embed")}
    return {"wg": ("embed", "ff"), "wu": ("embed", "ff"),
            "wd": ("ff", "embed")}


def _act(cfg: ModelConfig, x):
    if cfg.act == "silu":
        return jax.nn.silu(x)
    if cfg.act == "gelu":
        return jax.nn.gelu(x)
    if cfg.act == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(cfg.act)


def mlp(params, cfg: ModelConfig, x):
    dt = x.dtype
    if cfg.act == "relu2":
        h = _act(cfg, jnp.einsum("bsd,df->bsf", x, params["wu"].astype(dt)))
    else:
        g = jnp.einsum("bsd,df->bsf", x, params["wg"].astype(dt))
        u = jnp.einsum("bsd,df->bsf", x, params["wu"].astype(dt))
        h = _act(cfg, g) * u
    h = shard(h, "batch", "seq", "ff")
    return jnp.einsum("bsf,fd->bsd", h, params["wd"].astype(dt))


# ---------------------------------------------------------------------------
# Mixture of Experts — expert-parallel via shard_map (production path) with a
# pure-local fallback (single-device smoke tests).
#
# Layout: tokens are sharded over the data axes and *replicated* over the
# `model` axis; expert weights are sharded E over `model` (EP) and d over the
# FSDP axis. Each device routes its local tokens, builds a capacity buffer for
# ITS experts only (local scatter — no giant (T,E,C) dispatch tensor), runs the
# expert FFN, gathers back, and a single psum over `model` combines expert
# contributions (Megatron-style). FSDP weight shards are all-gathered
# explicitly inside the shard_map (DeepSeek-style EP+FSDP).
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": _dense_init(ks[0], (d, e), d),
        "wg": _dense_init(ks[1], (e, d, f), d),
        "wu": _dense_init(ks[2], (e, d, f), d),
        "wd": _dense_init(ks[3], (e, f, d), f),
    }


def moe_axes():
    return {"router": ("embed_tbl", None),
            "wg": ("experts", "embed", "expert_ff"),
            "wu": ("experts", "embed", "expert_ff"),
            "wd": ("experts", "expert_ff", "embed")}


def _moe_route(cfg: ModelConfig, xt, router, e_offset, E_loc, C):
    """Routing + slot bookkeeping (cheap int32 work, no (T, d) traffic).

    Returns (gate_vals (T, k), le/lp/keep per slot, aux)."""
    T, _ = xt.shape
    E, k = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True),
                                     1e-9, None)
    me = probs.mean(0)
    ce = jnp.zeros((E,), jnp.float32)
    counts = jnp.zeros((E,), jnp.int32)
    slot_le, slot_lp, slot_keep = [], [], []
    for s in range(k):
        e_s = gate_idx[:, s]  # (T,)
        oh = jax.nn.one_hot(e_s, E, dtype=jnp.int32)  # (T, E)
        pos = counts[None, :] + jnp.cumsum(oh, axis=0) - oh
        pos_s = jnp.take_along_axis(pos, e_s[:, None], axis=1)[:, 0]
        counts = counts + oh.sum(0)
        ce = ce + oh.sum(0).astype(jnp.float32)
        is_local = (e_s >= e_offset) & (e_s < e_offset + E_loc)
        keep = (pos_s < C) & is_local
        slot_le.append(jnp.clip(e_s - e_offset, 0, E_loc - 1))
        slot_lp.append(jnp.clip(pos_s, 0, C - 1))
        slot_keep.append(keep)
    aux = E * jnp.sum(me * (ce / (T * k)))
    return gate_vals, slot_le, slot_lp, slot_keep, aux


def _moe_inner(cfg: ModelConfig, xt, router, wg, wu, wd, e_offset, capacity):
    """Route + dispatch + expert FFN + combine for the local token block
    against a contiguous block of E_loc experts starting at e_offset.

    xt: (T, d) local tokens. wg/wu/wd: (E_loc, d, f) local expert weights
    (already FSDP-gathered). Returns (out (T, d), aux_loss scalar).

    Dispatch is *index-based*: token row-indices are scattered into an
    (E_loc, C) int32 table (drop-mode for over-capacity/non-local slots) and
    the buffer is a single row-gather. The earlier formulation scattered a
    keep-masked (T, d) copy of the activations per slot — ~k x T x d bytes
    of pure zeros per layer (measured: the dominant memory-roofline term of
    the MoE cells, see EXPERIMENTS.md §Perf iteration 1).
    """
    T, d = xt.shape
    E, k = cfg.n_experts, cfg.top_k
    E_loc = wg.shape[0]
    dt = xt.dtype
    C = capacity

    gate_vals, slot_le, slot_lp, slot_keep, aux = _moe_route(
        cfg, xt, router, e_offset, E_loc, C)

    # ---- dispatch: scatter token indices, gather rows once ----
    idx_tbl = jnp.full((E_loc, C), T, jnp.int32)  # T = dummy row
    token_ids = jnp.arange(T, dtype=jnp.int32)
    for s in range(k):
        le = jnp.where(slot_keep[s], slot_le[s], E_loc)  # drop -> OOB
        idx_tbl = idx_tbl.at[le, slot_lp[s]].set(token_ids, mode="drop")
    x_pad = jnp.concatenate([xt, jnp.zeros((1, d), dt)], axis=0)
    buf = jnp.take(x_pad, idx_tbl.reshape(-1), axis=0)
    buf = buf.reshape(E_loc, C, d)

    g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(dt))
    u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(dt))
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, wd.astype(dt))  # (E_loc, C, d)

    # ---- combine: per-slot row gather weighted by the gate ----
    out = jnp.zeros((T, d), dt)
    flat = out_buf.reshape(E_loc * C, d)
    for s in range(k):
        rows = jnp.take(flat, slot_le[s] * C + slot_lp[s], axis=0)
        gate = jnp.where(slot_keep[s], gate_vals[:, s], 0.0)
        out = out + rows * gate[:, None].astype(dt)
    return out, aux


def _capacity(cfg: ModelConfig, T: int) -> int:
    """Capacity per expert. Decode-sized token counts get drop-free
    capacity (C = T); training batches use the capacity-factor formula."""
    C = max(int(T * cfg.top_k / cfg.n_experts * cfg.capacity_factor), 1)
    if T <= 64:
        C = max(C, T)
    return min(C, T)


def _moe_inner_dsharded(cfg: ModelConfig, xt, router, wg, wu, wd,
                        e_offset, capacity, fsdp_axis):
    """Small-T (decode) expert FFN against d-sharded weights: partial
    contraction over the local d-shard + psum, avoiding the per-layer
    (E_loc, d, f) weight all-gather that dominates decode collectives."""
    T, d = xt.shape
    E_loc = wg.shape[0]
    d_shard = wg.shape[1]
    dt = xt.dtype
    C = capacity

    gate_vals, slot_le, slot_lp, slot_keep, aux = _moe_route(
        cfg, xt, router, e_offset, E_loc, C)

    idx_tbl = jnp.full((E_loc, C), T, jnp.int32)
    token_ids = jnp.arange(T, dtype=jnp.int32)
    for s in range(cfg.top_k):
        le = jnp.where(slot_keep[s], slot_le[s], E_loc)
        idx_tbl = idx_tbl.at[le, slot_lp[s]].set(token_ids, mode="drop")
    x_pad = jnp.concatenate([xt, jnp.zeros((1, d), dt)], axis=0)
    buf = jnp.take(x_pad, idx_tbl.reshape(-1), axis=0).reshape(E_loc, C, d)

    # slice my d-shard of the dispatched rows, contract, psum partials
    shard_i = jax.lax.axis_index(fsdp_axis)
    buf_d = jax.lax.dynamic_slice_in_dim(buf, shard_i * d_shard, d_shard,
                                         axis=2)
    g = jnp.einsum("ecd,edf->ecf", buf_d, wg.astype(dt))
    u = jnp.einsum("ecd,edf->ecf", buf_d, wu.astype(dt))
    g = jax.lax.psum(g, fsdp_axis)
    u = jax.lax.psum(u, fsdp_axis)
    h = jax.nn.silu(g) * u
    out_d = jnp.einsum("ecf,efd->ecd", h, wd.astype(dt))  # d-sharded out
    out_buf = jax.lax.all_gather(out_d, fsdp_axis, axis=2, tiled=True)

    out = jnp.zeros((T, d), dt)
    flat = out_buf.reshape(E_loc * C, d)
    for s in range(cfg.top_k):
        rows = jnp.take(flat, slot_le[s] * C + slot_lp[s], axis=0)
        gate = jnp.where(slot_keep[s], gate_vals[:, s], 0.0)
        out = out + rows * gate[:, None].astype(dt)
    return out, aux


_SHARD_MAP_NO_CHECK_KW = None


def _shard_map_no_check_kw(shard_map):
    """Cached: pre-0.5 jax spells shard_map's check_vma kwarg check_rep."""
    global _SHARD_MAP_NO_CHECK_KW
    if _SHARD_MAP_NO_CHECK_KW is None:
        import inspect
        _SHARD_MAP_NO_CHECK_KW = (
            "check_vma"
            if "check_vma" in inspect.signature(shard_map).parameters
            else "check_rep")
    return _SHARD_MAP_NO_CHECK_KW


def moe(params, cfg: ModelConfig, x):
    """Top-k MoE. Returns (out, aux_loss). Expert-parallel when a mesh with a
    `model` axis is active; pure local otherwise."""
    from repro.parallel.sharding import current_rules
    from jax.sharding import PartitionSpec as P
    try:
        from jax import shard_map
    except ImportError:  # moved out of jax.experimental in newer jax
        from jax.experimental.shard_map import shard_map

    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    rules = current_rules()
    use_ep = (rules is not None and rules.mesh is not None
              and rules.physical("experts") is not None)

    if not use_ep:
        T = B * S
        C = _capacity(cfg, T)
        out, aux = _moe_inner(cfg, x.reshape(T, d), params["router"],
                              params["wg"], params["wu"], params["wd"], 0, C)
        return out.reshape(B, S, d), aux

    mesh = rules.mesh
    ep_axis = rules.physical("experts")          # e.g. "model"
    fsdp_axis = rules.physical("embed")          # e.g. "data" (may be None)
    batch_axis = rules.physical("batch")         # e.g. ("pod", "data")
    n_ep = mesh.shape[ep_axis] if isinstance(ep_axis, str) else 1
    batch_names = ((batch_axis,) if isinstance(batch_axis, str)
                   else tuple(batch_axis or ()))
    n_dp = 1
    for a in batch_names:
        n_dp *= mesh.shape[a]

    T_loc = max((B // max(n_dp, 1)) * S, S)
    C = _capacity(cfg, T_loc)
    E_loc = E // n_ep

    x_spec = P(batch_axis, None, None)

    def sharded_moe(xb, router, wg, wu, wd):
        # xb: (B_loc, S, d); w*: (E_loc, d_shard, f)
        b, s, dd = xb.shape
        T = b * s
        e_off = jax.lax.axis_index(ep_axis) * E_loc
        if fsdp_axis is None:
            out, aux = _moe_inner(cfg, xb.reshape(T, dd), router,
                                  wg, wu, wd, e_off, C)
        elif T <= 1024:
            # decode-sized T: gathering (E_loc, d, f) weights costs far
            # more than the activations — contract against the local
            # d-shard and psum the partial sums instead (§Perf iter. 2)
            out, aux = _moe_inner_dsharded(
                cfg, xb.reshape(T, dd), router, wg, wu, wd, e_off, C,
                fsdp_axis)
        else:
            wg_full = jax.lax.all_gather(wg, fsdp_axis, axis=1, tiled=True)
            wu_full = jax.lax.all_gather(wu, fsdp_axis, axis=1, tiled=True)
            wd_full = jax.lax.all_gather(wd, fsdp_axis, axis=2, tiled=True)
            out, aux = _moe_inner(cfg, xb.reshape(T, dd), router,
                                  wg_full, wu_full, wd_full, e_off, C)
        out = jax.lax.psum(out, ep_axis)
        aux = jax.lax.pmean(aux, batch_names) if batch_names else aux
        return out.reshape(b, s, dd), aux

    no_check = _shard_map_no_check_kw(shard_map)
    fn = shard_map(
        sharded_moe, mesh=mesh,
        in_specs=(x_spec, P(None, None),
                  P(ep_axis, fsdp_axis, None) if fsdp_axis else P(ep_axis, None, None),
                  P(ep_axis, fsdp_axis, None) if fsdp_axis else P(ep_axis, None, None),
                  P(ep_axis, None, fsdp_axis) if fsdp_axis else P(ep_axis, None, None)),
        out_specs=(x_spec, P()),
        **{no_check: False})
    out, aux = fn(x, params["router"], params["wg"], params["wu"],
                  params["wd"])
    return out, aux


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, cfg: ModelConfig):
    p = {"embedding": _embed_init(key, (cfg.vocab_size, cfg.d_model))}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        p["unembed"] = _dense_init(k2, (cfg.d_model, cfg.vocab_size),
                                   cfg.d_model)
    return p


def embed_axes(cfg: ModelConfig):
    ax = {"embedding": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        ax["unembed"] = ("embed", "vocab")
    return ax


def embed(params, cfg: ModelConfig, tokens):
    dt = compute_dtype(cfg)
    x = jnp.take(params["embedding"].astype(dt), tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), dt)
    return x


def unembed(params, cfg: ModelConfig, x):
    dt = x.dtype
    if cfg.tie_embeddings:
        w = params["embedding"].astype(dt).T
    else:
        w = params["unembed"].astype(dt)
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    return shard(logits, "batch", "seq", "vocab")
