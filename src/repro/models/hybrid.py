"""Zamba2-style hybrid: Mamba-2 backbone with a *shared* attention+MLP block
applied after every ``attn_every``-th SSM layer (true weight sharing — one
parameter set, nine invocations for the 54-layer config, each with its own
KV history).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mamba2
from repro.models.config import ModelConfig
from repro.parallel.sharding import shard


def _n_shared_calls(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.attn_every


def init(cfg: ModelConfig, key) -> dict:
    k_embed, k_layers, k_shared, k_mlp = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: mamba2.init_block(k, cfg))(layer_keys)
    return {
        "embed": L.init_embed(k_embed, cfg),
        "layers": stacked,
        "shared": {
            "ln1": L.init_rmsnorm(cfg.d_model),
            "attn": L.init_attention(k_shared, cfg),
            "ln2": L.init_rmsnorm(cfg.d_model),
            "mlp": L.init_mlp(k_mlp, cfg),
        },
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }


def param_axes(cfg: ModelConfig):
    stack = jax.tree.map(lambda axes: (None,) + axes, mamba2.block_axes(cfg),
                         is_leaf=lambda x: isinstance(x, tuple))
    return {
        "embed": L.embed_axes(cfg),
        "layers": stack,
        "shared": {
            "ln1": L.rmsnorm_axes(),
            "attn": L.attention_axes(cfg),
            "ln2": L.rmsnorm_axes(),
            "mlp": L.mlp_axes(cfg),
        },
        "final_norm": L.rmsnorm_axes(),
    }


def _shared_block(cfg: ModelConfig, shared, x, angles):
    a_in = L.rmsnorm(shared["ln1"], x, cfg.norm_eps)
    x = x + L.attention(shared["attn"], cfg, a_in, angles=angles, causal=True)
    m_in = L.rmsnorm(shared["ln2"], x, cfg.norm_eps)
    x = x + L.mlp(shared["mlp"], cfg, m_in)
    return shard(x, "batch", "seq", "act_embed")


def apply_hidden(cfg: ModelConfig, params, batch):
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed(params["embed"], cfg, tokens)
    x = shard(x, "batch", "seq", "act_embed")
    positions = jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)
    angles = L.rope_angles(positions, cfg.head_dim, cfg.rope_theta)

    k = cfg.attn_every
    n_seg = _n_shared_calls(cfg)
    seg_params = jax.tree.map(
        lambda a: a[: n_seg * k].reshape((n_seg, k) + a.shape[1:]),
        params["layers"])
    shared = params["shared"]

    mamba_blk = mamba2._remat(
        cfg, lambda pp, xx: mamba2.block_apply(pp, cfg, xx))

    def seg_body(x, p_seg):
        def inner(x, p):
            return x + mamba_blk(p, x), None
        x, _ = jax.lax.scan(inner, x, p_seg)
        x = _shared_block(cfg, shared, x, angles)
        return x, None

    x, _ = jax.lax.scan(seg_body, x, seg_params)
    # tail SSM layers (if n_layers % attn_every != 0)
    for li in range(n_seg * k, cfg.n_layers):
        p = jax.tree.map(lambda a: a[li], params["layers"])
        x = x + mamba_blk(p, x)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


def apply(cfg: ModelConfig, params, batch):
    x, aux = apply_hidden(cfg, params, batch)
    logits = L.unembed(params["embed"], cfg, x)
    return logits, aux


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    ssm = mamba2.init_cache(cfg, batch, max_len, dtype)
    n_calls = _n_shared_calls(cfg)
    attn = L.init_kv_cache(cfg, batch, max_len, n_calls, dtype)
    return {"ssm": ssm, "attn": attn}


def cache_axes(cfg: ModelConfig):
    return {"ssm": mamba2.cache_axes(cfg), "attn": L.kv_cache_axes()}


def decode_step(cfg: ModelConfig, params, cache, tokens):
    B = tokens.shape[0]
    x = L.embed(params["embed"], cfg, tokens)
    idx = cache["attn"]["len"][0, 0]
    pos = jnp.broadcast_to(idx, (B, 1)).astype(jnp.int32)
    angles = L.rope_angles(pos, cfg.head_dim, cfg.rope_theta)

    k = cfg.attn_every
    n_seg = _n_shared_calls(cfg)
    seg_in = jax.tree.map(
        lambda a: a[: n_seg * k].reshape((n_seg, k) + a.shape[1:]),
        (params["layers"], cache["ssm"]))
    shared = params["shared"]

    def seg_body(x, scanned):
        (p_seg, c_seg), attn_cache = scanned

        def inner(x, pc):
            p, c = pc
            out, nc = mamba2.block_decode(p, cfg, x, c)
            return x + out, nc

        x, new_ssm = jax.lax.scan(inner, x, (p_seg, c_seg))
        a_in = L.rmsnorm(shared["ln1"], x, cfg.norm_eps)
        attn, new_attn = L.attention_decode(shared["attn"], cfg, a_in,
                                            attn_cache, angles=angles)
        x = x + attn
        m_in = L.rmsnorm(shared["ln2"], x, cfg.norm_eps)
        x = x + L.mlp(shared["mlp"], cfg, m_in)
        return x, (new_ssm, new_attn)

    x, (new_ssm, new_attn) = jax.lax.scan(seg_body, x,
                                          (seg_in, cache["attn"]))
    new_ssm = jax.tree.map(
        lambda a: a.reshape((n_seg * k,) + a.shape[2:]), new_ssm)
    for li in range(n_seg * k, cfg.n_layers):
        p = jax.tree.map(lambda a: a[li], params["layers"])
        c = jax.tree.map(lambda a: a[li], cache["ssm"])
        out, nc = mamba2.block_decode(p, cfg, x, c)
        x = x + out
        new_ssm = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b[None]], axis=0), new_ssm, nc)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], cfg, x)
    return logits, {"ssm": new_ssm, "attn": new_attn}
