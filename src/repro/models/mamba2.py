"""Mamba-2 (state-space duality) language model.

The SSD forward uses the chunked dual form: quadratic attention-like compute
inside fixed-length chunks (MXU-friendly matmuls) and a linear recurrence
carrying the (H, P, N) state across chunks. The single-step decode carries a
constant-size state — this is what makes the ``long_500k`` cell feasible.

``repro.kernels.ssd_scan`` is the Pallas TPU version of the chunked form;
this file is also its jnp reference when ``use_pallas=False``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.parallel.sharding import shard


# ---------------------------------------------------------------------------
# SSD core (chunked dual form)
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, A, B, C, chunk: int):
    """x: (b, S, H, P); dt: (b, S, H); A: (H,) (negative);
    B, C: (b, S, G, N) with H % G == 0. Returns (y (b,S,H,P),
    final_state (b,H,P,N))."""
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2)  # (b,S,H,N)
    Ch = jnp.repeat(C, rep, axis=2)

    nc = S // chunk
    xc = x.reshape(b, nc, chunk, H, P)
    dtc = dt.reshape(b, nc, chunk, H).astype(jnp.float32)
    Bc = Bh.reshape(b, nc, chunk, H, N)
    Cc = Ch.reshape(b, nc, chunk, H, N)

    a = dtc * A  # (b,nc,L,H) log-decay, negative
    cum = jnp.cumsum(a, axis=2)  # within-chunk cumulative
    seg_end = cum[:, :, -1, :]  # (b,nc,H)

    # ---- intra-chunk (quadratic within chunk) ----
    CB = jnp.einsum("bclhn,bcmhn->bchlm", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))
    cumh = cum.transpose(0, 1, 3, 2)  # (b,nc,H,L)
    seg = cumh[:, :, :, :, None] - cumh[:, :, :, None, :]  # cum[l]-cum[m]
    idx = jnp.arange(chunk)
    causal = idx[:, None] >= idx[None, :]
    # mask BEFORE exp: anti-causal entries have positive exponents that
    # would overflow to inf (inf * 0 = nan)
    decay = jnp.exp(jnp.where(causal, seg, -jnp.inf))
    M = CB * decay * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchlm,bcmhp->bclhp", M, xc.astype(jnp.float32))

    # ---- chunk states ----
    # state_c = sum_m exp(seg_end - cum[m]) * dt[m] * B[m] (outer) x[m]
    w = jnp.exp(seg_end[:, :, None, :] - cum) * dtc  # (b,nc,L,H)
    states = jnp.einsum("bclhn,bclh,bclhp->bchnp", Bc.astype(jnp.float32),
                        w, xc.astype(jnp.float32))  # (b,nc,H,N,P)

    # ---- inter-chunk recurrence ----
    seg_decay = jnp.exp(seg_end)  # (b,nc,H)

    def scan_f(h, inp):
        st, sd = inp  # (b,H,N,P), (b,H)
        h_next = h * sd[:, :, None, None] + st
        return h_next, h  # emit state *entering* the chunk

    h0 = jnp.zeros((b, H, N, P), jnp.float32)
    hT, h_in = jax.lax.scan(scan_f, h0,
                            (states.transpose(1, 0, 2, 3, 4),
                             seg_decay.transpose(1, 0, 2)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)  # (b,nc,H,N,P)

    # y_inter[l] = C[l] . (h_in * exp(cum[l]))
    y_inter = jnp.einsum("bclhn,bchnp,bclh->bclhp", Cc.astype(jnp.float32),
                         h_in, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(b, S, H, P)
    return y.astype(x.dtype), hT


def ssd_sequential(x, dt, A, B, C, h0=None):
    """Step-by-step oracle (used by tests and as the decode rule).

    Same signature as ssd_chunked; O(S) sequential scan."""
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    xf = x.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp  # (b,H,P), (b,H), (b,H,N), (b,H,N)
        decay = jnp.exp(dtt * A)[:, :, None, None]  # (b,H,1,1)
        h = h * decay + (dtt[:, :, None] * Bt)[:, :, :, None] * xt[:, :, None, :]
        y = jnp.einsum("bhn,bhnp->bhp", Ct, h)
        return h, y

    h0 = jnp.zeros((b, H, N, P), jnp.float32) if h0 is None else h0
    hT, ys = jax.lax.scan(step, h0, (xf.transpose(1, 0, 2, 3),
                                     dtf.transpose(1, 0, 2),
                                     Bh.transpose(1, 0, 2, 3),
                                     Ch.transpose(1, 0, 2, 3)))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), hT


# ---------------------------------------------------------------------------
# Mamba-2 block
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig):
    d, di = cfg.d_model, cfg.d_inner
    g, n, nh, w = (cfg.ssm_n_groups, cfg.ssm_state, cfg.ssm_heads,
                   cfg.ssm_conv_width)
    ks = jax.random.split(key, 8)
    return {
        "norm": L.init_rmsnorm(d),
        "wz": L._dense_init(ks[0], (d, di), d),
        "wx": L._dense_init(ks[1], (d, di), d),
        "wB": L._dense_init(ks[2], (d, g * n), d),
        "wC": L._dense_init(ks[3], (d, g * n), d),
        "wdt": L._dense_init(ks[4], (d, nh), d),
        "conv_x": L._dense_init(ks[5], (w, di), w),
        "conv_B": L._dense_init(ks[6], (w, g * n), w),
        "conv_C": L._dense_init(ks[7], (w, g * n), w),
        "A_log": jnp.zeros((nh,), jnp.float32),  # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "gate_norm": L.init_rmsnorm(di),
        "wo": L._dense_init(ks[0], (di, d), di),
    }


def block_axes(cfg: ModelConfig):
    return {
        "norm": L.rmsnorm_axes(),
        "wz": ("embed", "ssm_inner"),
        "wx": ("embed", "ssm_inner"),
        "wB": ("embed", None),
        "wC": ("embed", None),
        "wdt": ("embed", "ssm_heads"),
        "conv_x": (None, "ssm_inner"),
        "conv_B": (None, None),
        "conv_C": (None, None),
        "A_log": ("ssm_heads",),
        "D": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "gate_norm": {"scale": ("ssm_inner",)},
        "wo": ("ssm_inner", "embed"),
    }


def _causal_depthwise_conv(x, w):
    """x: (B, S, C); w: (W, C). Causal depthwise conv, left-padded."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
    return out


def block_apply(p, cfg: ModelConfig, x):
    """x: (B, S, d) -> (B, S, d). Full-sequence (train/prefill)."""
    dt_ = x.dtype
    h = L.rmsnorm(p["norm"], x, cfg.norm_eps)
    z = jnp.einsum("bsd,de->bse", h, p["wz"].astype(dt_))
    xr = jnp.einsum("bsd,de->bse", h, p["wx"].astype(dt_))
    Br = jnp.einsum("bsd,de->bse", h, p["wB"].astype(dt_))
    Cr = jnp.einsum("bsd,de->bse", h, p["wC"].astype(dt_))
    dt = jnp.einsum("bsd,dh->bsh", h, p["wdt"].astype(dt_))

    xr = jax.nn.silu(_causal_depthwise_conv(xr, p["conv_x"].astype(dt_)))
    Br = jax.nn.silu(_causal_depthwise_conv(Br, p["conv_B"].astype(dt_)))
    Cr = jax.nn.silu(_causal_depthwise_conv(Cr, p["conv_C"].astype(dt_)))
    xr = shard(xr, "batch", "seq", "ssm_inner")

    B_, S, _ = x.shape
    nh, hd = cfg.ssm_heads, cfg.ssm_head_dim
    g, n = cfg.ssm_n_groups, cfg.ssm_state
    xh = xr.reshape(B_, S, nh, hd)
    Bm = Br.reshape(B_, S, g, n)
    Cm = Cr.reshape(B_, S, g, n)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    if cfg.use_pallas:
        from repro.kernels import ops as kops
        y, _ = kops.ssd_scan(xh, dtv, A, Bm, Cm, chunk=cfg.ssm_chunk)
    elif S % cfg.ssm_chunk == 0 and S > cfg.ssm_chunk:
        y, _ = ssd_chunked(xh, dtv, A, Bm, Cm, cfg.ssm_chunk)
    else:
        y, _ = ssd_sequential(xh, dtv, A, Bm, Cm)
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(B_, S, cfg.d_inner)
    y = L.rmsnorm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, p["wo"].astype(dt_))


# ---------------------------------------------------------------------------
# single-step decode with carried state
# ---------------------------------------------------------------------------

def init_block_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    g, n = cfg.ssm_n_groups, cfg.ssm_state
    w = cfg.ssm_conv_width
    return {
        "state": jnp.zeros((batch, cfg.ssm_heads, n, cfg.ssm_head_dim),
                           jnp.float32),
        "conv_x": jnp.zeros((batch, w - 1, cfg.d_inner), dtype),
        "conv_B": jnp.zeros((batch, w - 1, g * n), dtype),
        "conv_C": jnp.zeros((batch, w - 1, g * n), dtype),
    }


def block_cache_axes():
    return {"state": ("batch", "ssm_heads", None, None),
            "conv_x": ("batch", None, "ssm_inner"),
            "conv_B": ("batch", None, None),
            "conv_C": ("batch", None, None)}


def _conv_step(buf, xt, w):
    """buf: (B, W-1, C) past inputs; xt: (B, C). Returns (y (B,C), new buf)."""
    seq = jnp.concatenate([buf, xt[:, None, :].astype(buf.dtype)], axis=1)
    y = jnp.einsum("bwc,wc->bc", seq.astype(xt.dtype), w)
    return y, seq[:, 1:, :]


def block_decode(p, cfg: ModelConfig, x, cache):
    """x: (B, 1, d). Returns (out (B, 1, d), new_cache)."""
    dt_ = x.dtype
    h = L.rmsnorm(p["norm"], x, cfg.norm_eps)[:, 0]  # (B, d)
    z = h @ p["wz"].astype(dt_)
    xr = h @ p["wx"].astype(dt_)
    Br = h @ p["wB"].astype(dt_)
    Cr = h @ p["wC"].astype(dt_)
    dt = h @ p["wdt"].astype(dt_)

    xr, conv_x = _conv_step(cache["conv_x"], xr, p["conv_x"].astype(dt_))
    Br, conv_B = _conv_step(cache["conv_B"], Br, p["conv_B"].astype(dt_))
    Cr, conv_C = _conv_step(cache["conv_C"], Cr, p["conv_C"].astype(dt_))
    xr, Br, Cr = jax.nn.silu(xr), jax.nn.silu(Br), jax.nn.silu(Cr)

    B_, = dt.shape[:1]
    nh, hd = cfg.ssm_heads, cfg.ssm_head_dim
    g, n = cfg.ssm_n_groups, cfg.ssm_state
    xh = xr.reshape(B_, nh, hd).astype(jnp.float32)
    Bm = jnp.repeat(Br.reshape(B_, g, n), nh // g, axis=1).astype(jnp.float32)
    Cm = jnp.repeat(Cr.reshape(B_, g, n), nh // g, axis=1).astype(jnp.float32)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, nh)
    A = -jnp.exp(p["A_log"])

    state = cache["state"]
    decay = jnp.exp(dtv * A)[:, :, None, None]
    state = state * decay + (dtv[:, :, None] * Bm)[:, :, :, None] * xh[:, :, None, :]
    y = jnp.einsum("bhn,bhnp->bhp", Cm, state)  # (B, nh, hd)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(B_, cfg.d_inner).astype(dt_)
    y = L.rmsnorm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = (y @ p["wo"].astype(dt_))[:, None, :]
    new_cache = {"state": state, "conv_x": conv_x, "conv_B": conv_B,
                 "conv_C": conv_C}
    return out, new_cache


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def init(cfg: ModelConfig, key) -> dict:
    k_embed, k_layers = jax.random.split(key, 2)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_block(k, cfg))(layer_keys)
    return {
        "embed": L.init_embed(k_embed, cfg),
        "layers": stacked,
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }


def param_axes(cfg: ModelConfig):
    stack = jax.tree.map(lambda axes: (None,) + axes, block_axes(cfg),
                         is_leaf=lambda x: isinstance(x, tuple))
    return {
        "embed": L.embed_axes(cfg),
        "layers": stack,
        "final_norm": L.rmsnorm_axes(),
    }


def apply_hidden(cfg: ModelConfig, params, batch):
    tokens = batch["tokens"]
    x = L.embed(params["embed"], cfg, tokens)
    x = shard(x, "batch", "seq", "act_embed")

    blk = _remat(cfg, lambda pp, xx: block_apply(pp, cfg, xx))

    def body(carry, p):
        return carry + blk(p, carry), None

    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


def apply(cfg: ModelConfig, params, batch):
    x, aux = apply_hidden(cfg, params, batch)
    logits = L.unembed(params["embed"], cfg, x)
    return logits, aux


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    del max_len  # O(1) state regardless of context length
    one = init_block_cache(cfg, batch, dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape).copy(),
        one)


def cache_axes(cfg: ModelConfig):
    return jax.tree.map(lambda axes: (None,) + axes, block_cache_axes(),
                        is_leaf=lambda x: isinstance(x, tuple))


def decode_step(cfg: ModelConfig, params, cache, tokens):
    x = L.embed(params["embed"], cfg, tokens)

    def body(x, scanned):
        p, c = scanned
        out, nc = block_decode(p, cfg, x, c)
        return x + out, nc

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], cfg, x)
    return logits, new_cache
