"""Whisper-style encoder-decoder transformer backbone.

Per the assignment the audio frontend (log-mel + conv downsampling) is a
STUB: ``input_specs`` provides precomputed frame embeddings
(B, encoder_seq, d_model). The encoder is bidirectional self-attention with
sinusoidal positions; the decoder is causal self-attention + cross-attention
with a learned positional table.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.parallel.sharding import shard


def sinusoid_positions(length: int, d: int) -> np.ndarray:
    log_timescale = np.log(10_000.0) / (d // 2 - 1)
    inv = np.exp(-log_timescale * np.arange(d // 2))
    t = np.arange(length)[:, None] * inv[None, :]
    return np.concatenate([np.sin(t), np.cos(t)], axis=1).astype(np.float32)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_enc_layer(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_rmsnorm(cfg.d_model),
        "attn": L.init_attention(k1, cfg),
        "ln2": L.init_rmsnorm(cfg.d_model),
        "mlp": L.init_mlp(k2, cfg),
    }


def _init_dec_layer(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": L.init_rmsnorm(cfg.d_model),
        "attn": L.init_attention(k1, cfg),
        "ln_x": L.init_rmsnorm(cfg.d_model),
        "cross": L.init_attention(k2, cfg),
        "ln2": L.init_rmsnorm(cfg.d_model),
        "mlp": L.init_mlp(k3, cfg),
    }


def init(cfg: ModelConfig, key, max_target_len: int = 4096) -> dict:
    ks = jax.random.split(key, 4)
    enc_keys = jax.random.split(ks[0], cfg.n_encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "embed": L.init_embed(ks[2], cfg),
        "pos_embed": L._embed_init(ks[3], (max_target_len, cfg.d_model)),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "enc_norm": L.init_rmsnorm(cfg.d_model),
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }


def param_axes(cfg: ModelConfig):
    def stack(ax):
        return jax.tree.map(lambda t: (None,) + t, ax,
                            is_leaf=lambda x: isinstance(x, tuple))
    enc = {"ln1": L.rmsnorm_axes(), "attn": L.attention_axes(cfg),
           "ln2": L.rmsnorm_axes(), "mlp": L.mlp_axes(cfg)}
    dec = {"ln1": L.rmsnorm_axes(), "attn": L.attention_axes(cfg),
           "ln_x": L.rmsnorm_axes(), "cross": L.attention_axes(cfg),
           "ln2": L.rmsnorm_axes(), "mlp": L.mlp_axes(cfg)}
    return {
        "embed": L.embed_axes(cfg),
        "pos_embed": ("seq_tbl", "embed_tbl"),
        "enc_layers": stack(enc),
        "dec_layers": stack(dec),
        "enc_norm": L.rmsnorm_axes(),
        "final_norm": L.rmsnorm_axes(),
    }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def encode(cfg: ModelConfig, params, frames):
    """frames: (B, F, d) precomputed frame embeddings (stub frontend)."""
    F = frames.shape[1]
    pos = jnp.asarray(sinusoid_positions(F, cfg.d_model))
    x = (frames + pos[None]).astype(L.compute_dtype(cfg))
    x = shard(x, "batch", "seq", "act_embed")

    def body(x, p):
        a_in = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        x = x + L.attention(p["attn"], cfg, a_in, causal=False)
        m_in = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + L.mlp(p["mlp"], cfg, m_in)
        return shard(x, "batch", "seq", "act_embed"), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _dec_layer(cfg: ModelConfig, x, p, enc_out):
    a_in = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    x = x + L.attention(p["attn"], cfg, a_in, causal=True)
    c_in = L.rmsnorm(p["ln_x"], x, cfg.norm_eps)
    x = x + L.attention(p["cross"], cfg, c_in, kv_x=enc_out, causal=False)
    m_in = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + L.mlp(p["mlp"], cfg, m_in)
    return shard(x, "batch", "seq", "act_embed")


def apply_hidden(cfg: ModelConfig, params, batch):
    """batch: {"frames": (B, F, d), "tokens": (B, S)}."""
    enc_out = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    S = tokens.shape[1]
    x = L.embed(params["embed"], cfg, tokens)
    x = x + params["pos_embed"][:S].astype(x.dtype)[None]
    x = shard(x, "batch", "seq", "act_embed")

    def _body(x, p):
        def fn(xx, pp):
            return _dec_layer(cfg, xx, pp, enc_out)
        if cfg.remat in ("dots", "full"):
            fn = jax.checkpoint(fn)
        return fn(x, p), None

    x, _ = jax.lax.scan(_body, x, params["dec_layers"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


def apply(cfg: ModelConfig, params, batch):
    x, aux = apply_hidden(cfg, params, batch)
    logits = L.unembed(params["embed"], cfg, x)
    return logits, aux


# ---------------------------------------------------------------------------
# decode: self-attention KV cache + precomputed cross KV
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    self_cache = L.init_kv_cache(cfg, batch, max_len, cfg.n_layers, dtype)
    F = cfg.encoder_seq
    cross = {
        "k": jnp.zeros((cfg.n_layers, batch, F, cfg.n_kv_heads,
                        cfg.head_dim), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, F, cfg.n_kv_heads,
                        cfg.head_dim), dtype),
    }
    return {"self": self_cache, "cross": cross}


def cache_axes(cfg: ModelConfig):
    return {
        "self": L.kv_cache_axes(),
        "cross": {"k": (None, "batch", None, "kv_heads", None),
                  "v": (None, "batch", None, "kv_heads", None)},
    }


def prefill_cross(cfg: ModelConfig, params, frames):
    """Precompute cross-attention K/V from the encoder output."""
    enc_out = encode(cfg, params, frames)
    dt = enc_out.dtype

    def body(_, p):
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wv"].astype(dt))
        if cfg.qkv_bias:
            k = k + p["cross"]["bk"].astype(dt)
            v = v + p["cross"]["bv"].astype(dt)
        return None, {"k": k, "v": v}

    _, cross = jax.lax.scan(body, None, params["dec_layers"])
    return cross


def decode_step(cfg: ModelConfig, params, cache, tokens):
    idx = cache["self"]["len"][0, 0]
    x = L.embed(params["embed"], cfg, tokens)
    x = x + jnp.take(params["pos_embed"], jnp.full((1,), idx),
                     axis=0).astype(x.dtype)[None]

    def body(x, scanned):
        p, self_c, cross_c = scanned
        a_in = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        attn, new_self = L.attention_decode(p["attn"], cfg, a_in, self_c)
        x = x + attn
        c_in = L.rmsnorm(p["ln_x"], x, cfg.norm_eps)
        dt = x.dtype
        q = jnp.einsum("bsd,dhk->bshk", c_in, p["cross"]["wq"].astype(dt))
        if cfg.qkv_bias:
            q = q + p["cross"]["bq"].astype(dt)
        out = L.mha_core(q, cross_c["k"].astype(dt), cross_c["v"].astype(dt),
                         causal=False, window=None)
        x = x + jnp.einsum("bshd,hdo->bso", out,
                           p["cross"]["wo"].astype(dt))
        m_in = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + L.mlp(p["mlp"], cfg, m_in)
        return x, new_self

    x, new_self = jax.lax.scan(
        body, x, (params["dec_layers"], cache["self"], cache["cross"]))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], cfg, x)
    return logits, {"self": new_self, "cross": cache["cross"]}
