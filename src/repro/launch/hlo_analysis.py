"""Loop-aware cost analysis over optimized (post-SPMD) HLO text.

Why this exists: ``compiled.cost_analysis()`` counts a ``while`` body ONCE,
regardless of trip count — so with scan-over-layers every per-layer FLOP,
byte and collective is under-counted by ~n_layers. This module re-derives
the three roofline terms by walking the HLO computation graph:

  * dot FLOPs from output shape x contraction size (2*M*N*K), elementwise /
    reduce FLOPs at 1/elem;
  * HBM bytes at *fusion boundaries* (operands + outputs of fusions and
    unfused ops — fusion internals stay on-chip, which models TPU better
    than the CPU backend's estimate);
  * collective wire bytes with ring-model factors (all-reduce 2x,
    all-gather/reduce-scatter ~1x of payload);
  * ``while`` bodies multiplied by trip counts (authoritative
    ``known_trip_count`` backend_config, else the loop-condition constant);
    nested loops multiply recursively. ``conditional`` takes the max branch.

All numbers are per-device (the module is the per-device SPMD program).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_ELEMWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "select", "compare", "and", "or", "not", "xor", "convert", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "sign", "remainder",
    "atan2", "logistic", "cbrt", "erf", "exponential-minus-one",
    "log-plus-one", "sine", "cosine", "tan", "clamp",
}

_NO_TRAFFIC = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "opt-barrier",
}

Shape = Tuple[str, List[int]]


def _shapes_in(text: str) -> List[Shape]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",") if x] if dims else []
        out.append((dtype, d))
    return out


def _bytes_of(shapes: List[Shape]) -> int:
    total = 0
    for dtype, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _elems_of(shapes: List[Shape]) -> int:
    total = 0
    for _, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    out_shapes: List[Shape]
    operands: List[str]
    attrs: str
    raw: str
    operand_shapes: List[Shape] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Optional[Dict[str, float]] = None
    dcn_bytes: float = 0.0  # subset of collective bytes crossing pods

    def __post_init__(self):
        if self.coll_bytes is None:
            self.coll_bytes = {k: 0.0 for k in _COLLECTIVES}

    def __iadd__(self, other: "Costs"):
        self.flops += other.flops
        self.bytes += other.bytes
        self.dcn_bytes += other.dcn_bytes
        for k in _COLLECTIVES:
            self.coll_bytes[k] += other.coll_bytes[k]
        return self

    def scaled(self, factor: float) -> "Costs":
        return Costs(self.flops * factor, self.bytes * factor,
                     {k: v * factor for k, v in self.coll_bytes.items()},
                     self.dcn_bytes * factor)

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())


# ---------------------------------------------------------------------------
# parsing
# ---------------------------------------------------------------------------

_COMP_HEADER = re.compile(
    r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{")
_OP_RE = re.compile(r"([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _split_instr(line: str):
    """'name = <shape> <op>(args), attrs' -> (name, shape, op, args, attrs).

    Tuple shapes may contain /*index=N*/ comments (with '=' inside), so this
    uses balanced-paren scanning, not a regex over the whole line."""
    stripped = line.strip()
    if stripped.startswith("ROOT "):
        stripped = stripped[5:]
    name, sep, rest = stripped.partition(" = ")
    if not sep or not name.startswith("%") and not re.match(r"[\w.\-]+$",
                                                            name):
        if not sep:
            return None
    name = name.lstrip("%")
    rest = rest.strip()
    if rest.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        shape_part = rest[:end + 1]
        rest2 = rest[end + 1:].strip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape_part = rest[:sp]
        rest2 = rest[sp + 1:].strip()
    m = _OP_RE.match(rest2)
    if not m:
        return None
    op = m.group(1)
    body = rest2[m.end():]
    depth, idx = 1, len(body)
    for i, ch in enumerate(body):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                idx = i
                break
    args, attrs = body[:idx], body[idx + 1:]
    return name, shape_part, op, args, attrs
_ATTR_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_ATTR_COND = re.compile(r"condition=%?([\w.\-]+)")
_ATTR_BRANCHES = re.compile(r"branch_computations={([^}]*)}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims={([\d,]*)}")
_TRIP_RE = re.compile(r'"known_trip_count":{"n":"(\d+)"}')


def parse_hlo(text: str):
    """Returns (computations: name -> [Instr], entry_name)."""
    comps: Dict[str, List[Instr]] = {}
    symbols: Dict[str, Dict[str, Instr]] = {}
    cur: Optional[str] = None
    entry: Optional[str] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            if "->" in stripped and stripped.endswith("{"):
                m = _COMP_HEADER.match(stripped)
                if m:
                    cur = m.group(2)
                    if m.group(1):
                        entry = cur
                    comps[cur] = []
                    symbols[cur] = {}
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        parts = _split_instr(line)
        if parts is None:
            continue
        name, shape_part, op, args, attrs = parts
        ins = Instr(name=name, op=op, out_shapes=_shapes_in(shape_part),
                    operands=_OPERAND_RE.findall(args), attrs=attrs,
                    raw=line)
        comps[cur].append(ins)
        symbols[cur][name] = ins
    # resolve operand shapes within each computation
    for cname, instrs in comps.items():
        table = symbols[cname]
        for ins in instrs:
            shapes: List[Shape] = []
            for oname in ins.operands:
                ref = table.get(oname)
                if ref is not None:
                    shapes.extend(ref.out_shapes)
            ins.operand_shapes = shapes
    return comps, entry


def _trip_count(ins: Instr, comps) -> int:
    m = _TRIP_RE.search(ins.attrs)
    if m:
        return int(m.group(1))
    m = _ATTR_COND.search(ins.attrs)
    if m:
        consts = []
        for ci in comps.get(m.group(1), []):
            cm = re.search(r"constant\((-?\d+)\)", ci.raw)
            if cm:
                consts.append(int(cm.group(1)))
        pos = [c for c in consts if c > 0]
        if pos:
            return max(pos)
    return 1


# ---------------------------------------------------------------------------
# cost evaluation
# ---------------------------------------------------------------------------

def _dot_flops(ins: Instr) -> float:
    out_elems = _elems_of(ins.out_shapes)
    m = _CONTRACT_RE.search(ins.attrs)
    k = 1
    if m and ins.operand_shapes:
        lhs_dims = ins.operand_shapes[0][1]
        for di in (int(x) for x in m.group(1).split(",") if x):
            if di < len(lhs_dims):
                k *= lhs_dims[di]
    return 2.0 * out_elems * k


def _conv_flops(ins: Instr) -> float:
    out_elems = _elems_of(ins.out_shapes)
    m = re.search(r"window={size=([\dx]+)", ins.attrs)
    k = 1
    if m:
        for x in m.group(1).split("x"):
            k *= int(x)
    return 2.0 * out_elems * k  # depthwise assumption


_IOTA_RG = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_EXPLICIT_RG = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _crosses_pods(attrs: str, pod_size: int = 256) -> bool:
    """True when a collective's replica group mixes device ids from
    different pods (id // pod_size differs) — those payloads ride the DCN.
    Handles both explicit and iota-format replica groups."""
    m = _EXPLICIT_RG.search(attrs)
    if m:
        ids = [int(x) for x in m.group(1).split(",") if x]
        return len({i // pod_size for i in ids}) > 1
    m = _IOTA_RG.search(attrs)
    if m:
        import numpy as np
        a, b = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",") if x]
        n = 1
        for d in dims:
            n *= d
        ids = np.arange(n).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",") if x]
            ids = ids.transpose(perm)
        groups = ids.reshape(a, b)
        pods = groups // pod_size
        return bool((pods != pods[:, :1]).any())
    return False


def _collective_wire_bytes(ins: Instr, base: str) -> float:
    out_b = _bytes_of(ins.out_shapes)
    in_b = _bytes_of(ins.operand_shapes)
    if base == "all-reduce":
        return 2.0 * out_b
    if base == "all-gather":
        return float(out_b)
    if base == "reduce-scatter":
        return float(in_b)
    if base == "all-to-all":
        return float(out_b)
    if base == "collective-permute":
        return float(out_b)
    return 0.0


class HloCostModel:
    def __init__(self, text: str):
        self.comps, self.entry = parse_hlo(text)
        self._memo: Dict[str, Costs] = {}

    def total(self) -> Costs:
        if not self.entry:
            raise ValueError("no ENTRY computation found")
        return self._comp_cost(self.entry)

    # ------------------------------------------------------------------
    def _comp_cost(self, name: str) -> Costs:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Costs()  # cycle guard
        total = Costs()
        table = {ci.name: ci for ci in self.comps.get(name, [])}
        for ins in self.comps.get(name, []):
            total += self._instr_cost(ins, table)
        self._memo[name] = total
        return total

    def _bf16_promoted(self, ins: Instr, table) -> bool:
        """True when a collective's f32 payload is a promoted bf16 value
        (XLA CPU promotes bf16 collectives; TPU runs them natively at
        bf16 — count wire bytes at the source dtype)."""
        if not ins.out_shapes or ins.out_shapes[0][0] != "f32":
            return False
        for oname in ins.operands:
            prod = table.get(oname)
            if prod is None:
                continue
            if prod.op == "convert" or (prod.op == "fusion"
                                        and "convert" in prod.name):
                if any(dt == "bf16" for dt, _ in prod.operand_shapes):
                    return True
        return False

    def _instr_cost(self, ins: Instr, table=None) -> Costs:
        table = table or {}
        op = ins.op
        c = Costs()
        base = op
        for suffix in ("-start", "-done"):
            if base.endswith(suffix):
                base = base[: -len(suffix)]

        if base in _COLLECTIVES:
            if op.endswith("-done"):
                return c
            wire = _collective_wire_bytes(ins, base)
            if self._bf16_promoted(ins, table):
                wire *= 0.5
            c.coll_bytes[base] += wire
            if _crosses_pods(ins.attrs):
                c.dcn_bytes += wire
            c.bytes += _bytes_of(ins.out_shapes)
            return c

        if op == "while":
            trips = _trip_count(ins, self.comps)
            inner = Costs()
            m = _ATTR_CALLS.search(ins.attrs)
            if m:
                inner += self._comp_cost(m.group(1))
            m = _ATTR_COND.search(ins.attrs)
            if m:
                inner += self._comp_cost(m.group(1))
            return inner.scaled(trips)

        if op == "conditional":
            m = _ATTR_BRANCHES.search(ins.attrs)
            branches = ([b.strip().lstrip("%") for b in m.group(1).split(",")]
                        if m else [])
            best = Costs()
            for b in branches:
                bc = self._comp_cost(b)
                if bc.flops >= best.flops:
                    best = bc
            return best

        if op in ("fusion", "call", "async-start"):
            m = _ATTR_CALLS.search(ins.attrs)
            callee = m.group(1) if m else None
            inner = self._comp_cost(callee) if callee else Costs()
            c.bytes += (self._fusion_operand_bytes(ins, callee)
                        + self._fusion_output_bytes(ins, callee))
            c.flops += inner.flops
            for k in _COLLECTIVES:
                c.coll_bytes[k] += inner.coll_bytes[k]
            return c

        if op in _NO_TRAFFIC:
            return c

        if op == "dot":
            c.flops += _dot_flops(ins)
            c.bytes += _bytes_of(ins.operand_shapes) + _bytes_of(ins.out_shapes)
            return c

        if op == "convolution":
            c.flops += _conv_flops(ins)
            c.bytes += _bytes_of(ins.operand_shapes) + _bytes_of(ins.out_shapes)
            return c

        if op in ("reduce", "reduce-window"):
            c.flops += _elems_of(ins.operand_shapes)
            c.bytes += _bytes_of(ins.operand_shapes) + _bytes_of(ins.out_shapes)
            return c

        if op in _ELEMWISE:
            c.flops += _elems_of(ins.out_shapes)
            c.bytes += _bytes_of(ins.operand_shapes) + _bytes_of(ins.out_shapes)
            return c

        # slicing / in-place ops: charge the *moved region*, not the full
        # buffer (XLA aliases the buffer; only the slice crosses HBM)
        if op in ("dynamic-slice", "slice", "gather"):
            c.bytes += 2 * _bytes_of(ins.out_shapes)
            return c
        if op == "dynamic-update-slice":
            upd = (ins.operand_shapes[1:2] if len(ins.operand_shapes) > 1
                   else ins.out_shapes)
            c.bytes += 2 * _bytes_of(upd)
            return c
        if op == "scatter":
            upd = (ins.operand_shapes[2:3] if len(ins.operand_shapes) > 2
                   else ins.out_shapes)
            c.bytes += 2 * _bytes_of(upd)
            return c
        if op == "broadcast":
            c.bytes += _bytes_of(ins.out_shapes)
            return c

        # data movement (copy, transpose, reshape, pad, concatenate,
        # sort, rng, custom-call, ...)
        c.bytes += _bytes_of(ins.operand_shapes) + _bytes_of(ins.out_shapes)
        return c

    # ------------------------------------------------------------------
    # fusion-boundary traffic with slice-awareness: an operand consumed
    # ONLY by dynamic-slice/gather inside the fusion contributes the slice
    # bytes; a root that is a dynamic-update-slice contributes the update
    # bytes (the buffer itself is aliased in place).
    # ------------------------------------------------------------------
    def _callee_params(self, callee: str):
        params = {}
        uses: Dict[str, list] = {}
        for ci in self.comps.get(callee, []):
            if ci.op == "parameter":
                pm = re.search(r"parameter\((\d+)\)", ci.raw)
                if pm:
                    params[int(pm.group(1))] = ci
            for oname in ci.operands:
                uses.setdefault(oname, []).append(ci)
        return params, uses

    def _fusion_operand_bytes(self, ins: Instr, callee) -> float:
        if not callee or callee not in self.comps:
            return float(_bytes_of(ins.operand_shapes))
        params, uses = self._callee_params(callee)
        total = 0.0
        for idx, _ in enumerate(ins.operands):
            p = params.get(idx)
            if p is None:
                continue
            consumers = uses.get(p.name, [])
            full = _bytes_of(p.out_shapes)
            if consumers and all(cns.op in ("dynamic-slice", "gather")
                                 for cns in consumers):
                total += min(full, sum(_bytes_of(cns.out_shapes)
                                       for cns in consumers))
            else:
                total += full
        return total

    def _fusion_output_bytes(self, ins: Instr, callee) -> float:
        full = float(_bytes_of(ins.out_shapes))
        if not callee or callee not in self.comps:
            return full
        instrs = self.comps[callee]
        by_name = {ci.name: ci for ci in instrs}
        root = instrs[-1] if instrs else None
        if root is None:
            return full
        producers = [root]
        if root.op == "tuple":
            producers = [by_name[o] for o in root.operands if o in by_name]
        total = 0.0
        for pr in producers:
            if pr.op == "dynamic-update-slice":
                upd = (pr.operand_shapes[1:2]
                       if len(pr.operand_shapes) > 1 else pr.out_shapes)
                total += _bytes_of(upd)
            else:
                total += _bytes_of(pr.out_shapes)
        return min(total, full) if total else full


def analyze(text: str) -> Costs:
    return HloCostModel(text).total()
