"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — FlowOS-RM builds meshes only when a slice is
launched.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model) — the `pod` axis is
    the slow DCN-class dimension (paper: the disaggregated network)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_from_lease(lease, mesh_shape: Tuple[int, ...],
                    axis_names: Tuple[str, ...]):
    """Build a mesh over a FlowOS-RM lease's devices."""
    devs = np.array(lease.jax_devices()).reshape(mesh_shape)
    return jax.sharding.Mesh(devs, axis_names)


def single_device_mesh():
    """1x1 mesh on the local device (smoke tests / examples on CPU)."""
    arr = np.array(jax.devices()[:1]).reshape(1, 1)
    return jax.sharding.Mesh(arr, ("data", "model"))
