"""End-to-end training driver through FlowOS-RM.

This is example (b)'s engine and the integration point for every subsystem:
the RM constructs a slice, the policy shards the model onto it, the data
pipeline feeds it, checkpoints flow async, and the elastic controller
watches for failures/stragglers at step boundaries.

Usage (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
      --steps 20 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import importlib
import time
from typing import Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.elastic import ElasticController
from repro.core.pool import DevicePool
from repro.core.rm import FlowOSRM
from repro.core.job import JobSpec, TaskSpec
from repro.data.pipeline import SyntheticLMDataset, make_data_iterator
from repro.models.config import ShapeConfig
from repro.models.registry import get_model, get_config
from repro.optim.adamw import AdamW
from repro.optim.schedule import cosine_schedule
from repro.parallel.policy import sharding_policy
from repro.parallel.sharding import sanitize_tree_specs, tree_specs
from repro.train import steps as S


def load_config(arch: str, smoke: bool):
    if smoke:
        mod_name = arch.replace(".", "_").replace("-", "_")
        mod = importlib.import_module(f"repro.configs.{mod_name}")
        return mod.smoke()
    return get_config(arch)


def run_training(cfg, *, steps: int, batch: int, seq: int,
                 mesh_shape=(1, 1), pool: Optional[DevicePool] = None,
                 ckpt_dir: Optional[str] = None, resume: bool = False,
                 lr: float = 3e-4, log_every: int = 10,
                 elastic: Optional[ElasticController] = None,
                 seed: int = 0):
    """Train on the given slice mesh; returns (final metrics, losses)."""
    model = get_model(cfg)
    shape = ShapeConfig("custom", seq, batch, "train")

    if pool is None:
        pool = DevicePool.from_jax_devices(jax.devices()[: int(np.prod(mesh_shape))],
                                           devices_per_node=1)
    rm = FlowOSRM(pool)
    losses = []
    result = {}

    def prepare(slice_):
        mesh = slice_.mesh
        rules = sharding_policy(cfg, shape, mesh)
        optimizer = AdamW(lr=lr, schedule=cosine_schedule(lr, 10, steps))
        step_fn = S.make_train_step(model, optimizer, rules)
        p_specs, opt_specs = S.state_specs(model, rules)
        p_struct = S.params_struct(model)
        p_specs = sanitize_tree_specs(mesh, p_specs, p_struct)
        from jax.sharding import NamedSharding
        from repro.optim.adamw import OptState
        from jax.sharding import PartitionSpec as P
        opt_specs = OptState(step=P(), mu=p_specs, nu=p_specs)
        def as_shard(t):
            return jax.tree.map(
                lambda s: NamedSharding(mesh, s), t,
                is_leaf=lambda x: isinstance(x, P))
        state_sharding = S.TrainState(as_shard(p_specs), as_shard(opt_specs))
        jitted = jax.jit(step_fn, in_shardings=(state_sharding, None),
                         donate_argnums=(0,))
        return {"jitted": jitted, "rules": rules,
                "state_sharding": state_sharding, "optimizer": optimizer}

    def task(slice_):
        exe = slice_.executable
        mesh = slice_.mesh
        ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        start_step = 0
        with mesh:
            if resume and ckpt and ckpt.latest_step() is not None:
                state = ckpt.restore(
                    shardings=jax.tree.map(lambda s: s,
                                           exe["state_sharding"]))
                start_step = ckpt.latest_step()
            else:
                params = model.init(cfg, jax.random.PRNGKey(seed))
                opt = exe["optimizer"].init(params)
                state = S.TrainState(params, opt)

            ds = SyntheticLMDataset(cfg, seq, batch, seed=seed)
            it = make_data_iterator(ds, start_step=start_step,
                                    stop_step=steps)
            t_start = time.perf_counter()
            for step_i, data in it:
                t0 = time.perf_counter()
                state, metrics = exe["jitted"](state, data)
                loss = float(metrics["loss"])
                losses.append(loss)
                dt = time.perf_counter() - t0
                if elastic is not None:
                    elastic.record_step({n: dt for n in
                                         slice_.lease.nodes})
                if step_i % log_every == 0 or step_i == steps - 1:
                    print(f"  step {step_i}: loss {loss:.4f} "
                          f"({dt*1e3:.0f} ms)")
                if ckpt and (step_i + 1) % 50 == 0:
                    ckpt.save(step_i + 1, state)
            if ckpt:
                ckpt.save(steps, state, blocking=True)
            result["steps_per_s"] = (len(losses)
                                     / (time.perf_counter() - t_start))
            result["final_loss"] = losses[-1] if losses else None
        return result

    n_dev = int(np.prod(mesh_shape))
    spec = JobSpec(name=f"train-{cfg.name}", tasks=[TaskSpec(
        name="train", n_devices=n_dev, mesh_shape=tuple(mesh_shape),
        axis_names=("data", "model"), arch=cfg.name, steps=steps,
        prepare_fn=prepare, task_fn=task)])
    try:
        job_id = rm.submit(spec)
        rec = rm.wait(job_id, timeout_s=3600)
    finally:
        rm.close()  # callers may pass a long-lived pool; drop our listener
    if rec.error:
        raise RuntimeError(rec.error)
    breakdown = rec.slices[0].breakdown() if rec.slices else {}
    return {**result, "losses": losses, "breakdown": breakdown,
            "job": rec.to_dict()}


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true",
                   help="use the reduced config (CPU-friendly)")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--ckpt-dir", type=str, default=None)
    p.add_argument("--resume", action="store_true")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    cfg = load_config(args.arch, args.smoke)
    out = run_training(cfg, steps=args.steps, batch=args.batch,
                       seq=args.seq, lr=args.lr, ckpt_dir=args.ckpt_dir,
                       resume=args.resume, seed=args.seed)
    b = out["breakdown"]
    total = sum(b.values())
    print(f"[train] {cfg.name}: final loss {out['final_loss']:.4f}, "
          f"{out['steps_per_s']:.2f} steps/s")
    print("[train] lifecycle: " + ", ".join(
        f"{k}={v:.2f}s" for k, v in b.items()))
    print(f"[train] construction+destruction overhead: "
          f"{(total - b.get('run_task', 0)) / max(total, 1e-9):.1%}")


if __name__ == "__main__":
    main()
