import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is how the distribution config is proven coherent without hardware:
``jax.jit(step, in_shardings=..., out_shardings=...).lower(...).compile()``
against 512 host-platform placeholder devices. Failures here (sharding
mismatch, OOM at compile, unsupported collective) are bugs in the system.

Outputs per cell: memory analysis (fits / doesn't), cost analysis (FLOPs,
bytes) and the collective schedule -> EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b \
      --shape train_4k [--multi-pod] [--out results.jsonl]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro.launch.analysis import (Roofline, collective_bytes,
                                   memory_analysis_dict, model_flops_for)
from repro.launch.mesh import make_production_mesh
from repro.models import SHAPES
from repro.models.registry import get_model, list_architectures
from repro.optim.adamw import AdamW
from repro.parallel.policy import sharding_policy
from repro.train import steps as S

# microbatch counts for cells whose transient activations exceed the
# 16 GB/chip budget at full batch (EXPERIMENTS.md §Perf iteration 8)
MICROBATCH = {
    # pure-DP cells already run at B_loc=1/device — splitting the batch
    # there breaks divisibility and *raises* peak (measured); only the
    # dp_ep MoE cell benefits.
    ("qwen3-moe-235b-a22b", "train_4k"): 4,
}

# cells skipped per the assignment's shape rules
SKIP_RULES = {
    # long_500k needs sub-quadratic attention: skip pure full-attention archs
    ("qwen2.5-3b", "long_500k"): "pure full attention",
    ("minitron-8b", "long_500k"): "pure full attention",
    ("smollm-360m", "long_500k"): "pure full attention",
    ("whisper-medium", "long_500k"): "pure full attention (enc-dec)",
    ("qwen2-vl-7b", "long_500k"): "pure full attention",
    ("qwen3-moe-235b-a22b", "long_500k"): "pure full attention",
    ("granite-moe-1b-a400m", "long_500k"): "pure full attention",
}


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool = False,
                verbose: bool = True, policy_overrides=None,
                cfg_overrides=None) -> dict:
    """Lower+compile one cell; returns the roofline record dict."""
    t0 = time.perf_counter()
    shape = SHAPES[shape_name]
    model = get_model(arch, **(cfg_overrides or {}))
    cfg = model.cfg
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = sharding_policy(cfg, shape, mesh, **(policy_overrides or {}))
    n_dev = mesh.devices.size

    kind, args, in_shardings = S.input_specs(model, shape, rules)
    optimizer = AdamW()

    # over-budget train cells use gradient accumulation + sqrt-remat
    # (§Perf iteration 8)
    n_micro = MICROBATCH.get((arch, shape_name), 1)
    if n_micro > 1 and not cfg_overrides:
        cfg_overrides = {"remat": "sqrt"}
        model = get_model(arch, **cfg_overrides)
        cfg = model.cfg
    if kind == "train":
        step_fn = S.make_train_step(model, optimizer, rules,
                                    n_microbatches=n_micro)
        donate = (0,)
    elif kind == "prefill":
        step_fn = S.make_prefill_step(model, rules)
        donate = ()
    else:
        step_fn = S.make_serve_step(model, rules)
        donate = (1,)

    with mesh:
        jitted = jax.jit(step_fn, in_shardings=in_shardings,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = memory_analysis_dict(compiled)
    xla_cost = compiled.cost_analysis() or {}
    # loop-aware analysis (scan bodies x trip counts) — see hlo_analysis.py
    from repro.launch.hlo_analysis import analyze
    hlo_text = compiled.as_text()
    costs = analyze(hlo_text)

    rl = Roofline(
        arch=arch, shape=shape_name,
        mesh="2x16x16" if multi_pod else "16x16", n_devices=n_dev,
        hlo_flops=costs.flops, hlo_bytes=costs.bytes,
        coll_bytes={k: int(v) for k, v in costs.coll_bytes.items()},
        cross_pod=multi_pod, model_flops=model_flops_for(cfg, shape),
        peak_memory=mem.get("peak_bytes"), dcn_bytes=costs.dcn_bytes)
    rec = rl.to_dict()
    rec.update({"kind": kind, "lower_s": round(t_lower, 2),
                "compile_s": round(t_compile, 2), "status": "ok",
                "memory": mem,
                "strategy": getattr(rules, "strategy", "tp"),
                "xla_flops_per_dev": float(xla_cost.get("flops", 0.0)),
                "xla_bytes_per_dev": float(
                    xla_cost.get("bytes accessed", 0.0))})
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']}: "
              f"compile {rec['compile_s']}s, "
              f"peak {mem.get('peak_bytes', 0)/1e9:.2f} GB/dev, "
              f"compute {rl.compute_s*1e3:.2f}ms "
              f"memory {rl.memory_s*1e3:.2f}ms "
              f"collective {rl.collective_s*1e3:.2f}ms "
              f"-> {rl.dominant}-bound, MFU {rl.mfu:.1%}")
        sys.stdout.flush()
    return rec


def run_all(multi_pod: bool, out_path=None, archs=None, shapes=None):
    records = []
    archs = archs or list_architectures()
    shapes = shapes or list(SHAPES)
    for arch in archs:
        for shape_name in shapes:
            if (arch, shape_name) in SKIP_RULES:
                rec = {"arch": arch, "shape": shape_name,
                       "mesh": "2x16x16" if multi_pod else "16x16",
                       "status": "skip",
                       "reason": SKIP_RULES[(arch, shape_name)]}
                print(f"[dryrun] {arch} x {shape_name}: SKIP "
                      f"({rec['reason']})")
            else:
                try:
                    rec = dryrun_cell(arch, shape_name, multi_pod)
                except Exception as e:  # noqa: BLE001 — report, keep going
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": "2x16x16" if multi_pod else "16x16",
                           "status": "error", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()}
                    print(f"[dryrun] {arch} x {shape_name}: ERROR {e}")
            records.append(rec)
            if out_path:
                with open(out_path, "a") as f:
                    f.write(json.dumps(rec) + "\n")
    return records


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", type=str, default=None)
    p.add_argument("--shape", type=str, default=None)
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--all", action="store_true")
    p.add_argument("--out", type=str, default=None)
    args = p.parse_args()

    if args.all:
        archs = [args.arch] if args.arch else None
        shapes = [args.shape] if args.shape else None
        recs = run_all(args.multi_pod, args.out, archs, shapes)
        bad = [r for r in recs if r["status"] == "error"]
        print(f"[dryrun] {len(recs)} cells: "
              f"{sum(r['status'] == 'ok' for r in recs)} ok, "
              f"{sum(r['status'] == 'skip' for r in recs)} skip, "
              f"{len(bad)} error")
        sys.exit(1 if bad else 0)
    assert args.arch and args.shape, "--arch and --shape (or --all)"
    rec = dryrun_cell(args.arch, args.shape, args.multi_pod)
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
