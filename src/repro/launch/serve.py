"""Serving driver: batched decode through a FlowOS-RM slice.

Implements the inference side of the paper's workload: a slice is
constructed for a serving job, requests are batched, prefill builds the KV
cache, and serve_step decodes token-by-token.

``--microbatches k`` (k > 1) switches to the disaggregated
prefill/decode meta-accelerator path (DESIGN.md §5): prefill runs on one
sub-slice, token decode on another, the KV cache hops the fabric between
them, and microbatch m decodes while m+1 prefills.

``--continuous`` runs the paged-KV continuous-batching serving plane
(DESIGN.md §10) on a Zipf-ragged workload: sequences join/retire every
decode step against one HBM page pool (the PR 1 free-run index as page
allocator), with the static-batch baseline timed alongside. Combine with
``--microbatches k`` to compute prompt KV on a disaggregated prefill
sub-slice and hop it into the decode engine over the PR 2 pipeline, so
prefill microbatches overlap in-flight decode.

Usage (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --batch 4 --prompt-len 32 --decode-len 16 [--microbatches 2]
  PYTHONPATH=src python -m repro.launch.serve --continuous \
      --requests 32 --lanes 8 [--microbatches 4]
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pool import DevicePool
from repro.core.rm import FlowOSRM
from repro.core.job import JobSpec, TaskSpec
from repro.core.meta_accel import LinkModel, MetaAccelerator, StageSpec
from repro.models.config import ShapeConfig
from repro.models.registry import get_model
from repro.launch.train import load_config
from repro.parallel.policy import sharding_policy
from repro.parallel.sharding import axis_rules
from repro.train import steps as S


def _init_decode_cache(model, cfg, params, rules, batch, max_len,
                       frames=None):
    """Fresh KV cache, including the audio cross-attention prefill.
    Shared by the FlowOS-RM serial path and the disaggregated prefill
    stage."""
    cache = model.init_cache(cfg, batch, max_len)
    if cfg.family == "audio":
        from repro.models import whisper as W
        with axis_rules(rules):
            cache["cross"] = W.prefill_cross(
                cfg, S.cast_params(cfg, params), frames)
    return cache


def _prefill_loop(fn, params, cache, prompts):
    """Token-by-token prefill (simple path; a fused prefill kernel is the
    production fast path)."""
    logits = None
    for t in range(prompts.shape[1]):
        logits, cache = fn(params, cache, prompts[:, t:t + 1])
    return logits, cache


def _greedy_decode(fn, params, cache, logits, decode_len):
    """Greedy argmax decode loop; returns the generated token block."""
    generated = []
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    for _ in range(decode_len):
        generated.append(tok)
        logits, cache = fn(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    return jnp.concatenate(generated, axis=1), logits


def run_serving(cfg, *, batch: int, prompt_len: int, decode_len: int,
                mesh_shape=(1, 1), seed: int = 0):
    model = get_model(cfg)
    assert model.decode_step is not None, f"{cfg.name} has no decode path"
    max_len = prompt_len + decode_len
    shape = ShapeConfig("serve", max_len, batch, "decode")
    pool = DevicePool.from_jax_devices(
        jax.devices()[: int(np.prod(mesh_shape))], devices_per_node=1)
    rm = FlowOSRM(pool)
    out = {}

    def prepare(slice_):
        rules = sharding_policy(cfg, shape, slice_.mesh)
        serve_fn = jax.jit(S.make_serve_step(model, rules),
                           donate_argnums=(1,))
        return {"serve": serve_fn, "rules": rules}

    def task(slice_):
        exe = slice_.executable
        rules = exe["rules"]
        with slice_.mesh:
            key = jax.random.PRNGKey(seed)
            params = model.init(cfg, key)
            prompts = jax.random.randint(key, (batch, prompt_len), 0,
                                         cfg.vocab_size)
            frames = None
            if cfg.family == "audio":
                frames = jax.random.normal(
                    key, (batch, cfg.encoder_seq, cfg.d_model)) * 0.02
            # cache init (and audio cross-prefill) stays outside the
            # timed region — prefill_s means the prompt-feed loop only,
            # same definition as before the prefill/decode refactor
            cache = _init_decode_cache(model, cfg, params, rules, batch,
                                       max_len, frames)
            t0 = time.perf_counter()
            logits, cache = _prefill_loop(exe["serve"], params, cache,
                                          prompts)
            prefill_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            tokens, logits = _greedy_decode(exe["serve"], params, cache,
                                            logits, decode_len)
            jax.block_until_ready(logits)
            decode_s = time.perf_counter() - t0
            out["tokens"] = np.asarray(tokens)
            out["prefill_s"] = prefill_s
            out["decode_tok_per_s"] = batch * decode_len / decode_s
        return out

    spec = JobSpec(name=f"serve-{cfg.name}", tasks=[TaskSpec(
        name="serve", n_devices=int(np.prod(mesh_shape)),
        mesh_shape=tuple(mesh_shape), axis_names=("data", "model"),
        arch=cfg.name, prepare_fn=prepare, task_fn=task)])
    rec = rm.wait(rm.submit(spec), timeout_s=3600)
    if rec.error:
        raise RuntimeError(rec.error)
    out["breakdown"] = rec.slices[0].breakdown()
    return out


def run_serving_pipelined(cfg, *, batch: int, prompt_len: int,
                          decode_len: int, microbatches: int = 2,
                          seed: int = 0, link: LinkModel = None):
    """Disaggregated prefill/decode serving (DESIGN.md §5): prefill on one
    sub-slice, token decode on another, the KV cache hopping the fabric
    between them. ``run_pipeline(microbatches=k)`` overlaps microbatch
    m's decode with m+1's prefill — the serving-side analogue of the
    paper's meta-accelerator stage split."""
    model = get_model(cfg)
    assert model.decode_step is not None, f"{cfg.name} has no decode path"
    if batch % microbatches:
        raise ValueError(f"batch={batch} must divide evenly into "
                         f"microbatches={microbatches} so each stage "
                         "keeps one compiled executable")
    max_len = prompt_len + decode_len
    shape = ShapeConfig("serve", max_len, batch // microbatches, "decode")
    # two virtual single-device sub-slices over the local device: the
    # pool sees distinct prefill/decode accelerator kinds
    pool = DevicePool.virtual(2, devices_per_node=1,
                              kinds={(0, 1): "prefill", (1, 2): "decode"})
    dev = jax.devices()[0]
    for d in pool._devices:
        d.device = dev
    meta = MetaAccelerator(pool, link=link)

    key = jax.random.PRNGKey(seed)
    params = model.init(cfg, key)
    prompts = jax.random.randint(key, (batch, prompt_len), 0,
                                 cfg.vocab_size)

    compiled = {}

    def serve_fn(slice_):
        # one jitted executable shared by both stages (identical 1x1
        # meshes); jit retraces per batch shape, so serial warmup and
        # microbatch chunks each compile exactly once
        if "fn" not in compiled:
            compiled["rules"] = sharding_policy(cfg, shape, slice_.mesh)
            compiled["fn"] = jax.jit(
                S.make_serve_step(model, compiled["rules"]),
                donate_argnums=(1,))
        return compiled["fn"]

    decode_busy_s = []  # appended only by the decode stage's worker

    def prefill_stage(slice_, payload):
        fn = serve_fn(slice_)
        toks = payload["prompts"]
        with slice_.mesh:
            cache = _init_decode_cache(model, cfg, params,
                                       compiled["rules"], toks.shape[0],
                                       max_len, payload.get("frames"))
            logits, cache = _prefill_loop(fn, params, cache, toks)
        return {"cache": cache, "logits": logits}

    def decode_stage(slice_, state):
        fn = serve_fn(slice_)
        t0 = time.perf_counter()
        with slice_.mesh:
            tokens, logits = _greedy_decode(fn, params, state["cache"],
                                            state["logits"], decode_len)
            jax.block_until_ready(logits)
        decode_busy_s.append(time.perf_counter() - t0)
        return tokens

    stages = [
        StageSpec(name="prefill", kind="prefill", n_devices=1,
                  mesh_shape=(1, 1), axis_names=("data", "model"),
                  stage_fn=prefill_stage),
        StageSpec(name="decode", kind="decode", n_devices=1,
                  mesh_shape=(1, 1), axis_names=("data", "model"),
                  stage_fn=decode_stage),
    ]
    slices = meta.allocate(stages)
    try:
        payload = {"prompts": prompts}
        if cfg.family == "audio":
            # generated once at full batch so microbatch chunks slice the
            # same rows the serial path sees (bit-exact comparison holds)
            payload["frames"] = jax.random.normal(
                key, (batch, cfg.encoder_seq, cfg.d_model)) * 0.02
        # warmup compiles both batch shapes outside the timed runs
        meta.run_pipeline(stages, slices, payload)
        meta.run_pipeline(stages, slices, payload,
                          microbatches=microbatches)
        t0 = time.perf_counter()
        serial_tokens = meta.run_pipeline(stages, slices, payload)
        serial_s = time.perf_counter() - t0
        decode_busy_s.clear()
        transfers_before = meta.transfer_totals()
        t0 = time.perf_counter()
        tokens = meta.run_pipeline(stages, slices, payload,
                                   microbatches=microbatches)
        pipelined_s = time.perf_counter() - t0
        transfers_after = meta.transfer_totals()
    finally:
        meta.release(slices)
    return {
        "tokens": np.asarray(tokens),
        "match": bool(np.array_equal(np.asarray(serial_tokens),
                                     np.asarray(tokens))),
        "serial_s": serial_s, "pipelined_s": pipelined_s,
        # decode-busy throughput, comparable to run_serving's metric
        "decode_tok_per_s": batch * decode_len / max(sum(decode_busy_s),
                                                     1e-9),
        # whole-request throughput including prefill and fabric hops
        "e2e_tok_per_s": batch * decode_len / pipelined_s,
        # fabric traffic of the timed pipelined request only (warmup and
        # serial-baseline hops excluded)
        "transfers": {k: transfers_after[k] - transfers_before[k]
                      for k in transfers_after},
    }


def run_serving_continuous(*, n_requests: int, lanes: int,
                           prompt_len: int = 8, page_size: int = 8,
                           max_new_cap: int = 64, zipf_a: float = 1.8,
                           microbatches: int = 1, seed: int = 0,
                           link: LinkModel = None,
                           compare_static: bool = True):
    """Continuous-batching serving plane (DESIGN.md §10) through a
    FlowOS-RM slice. The engine's KV page pool is sized to the static
    baseline's worst case, so both schedulers run at an *equal HBM page
    budget* and the speedup is pure scheduling. ``microbatches > 1``
    additionally disaggregates prefill onto its own sub-slice: prompt KV
    is computed there, hops the fabric (PR 2 data plane), and is ingested
    into the decode engine while later prefill microbatches are still in
    flight."""
    from repro.serve import (ContinuousEngine, LMConfig,
                             equal_page_budget, make_zipf_requests,
                             timed_drain, warmup_engine)
    from repro.serve import model as PM

    cfg = LMConfig(page_size=page_size)
    params = PM.init(cfg, jax.random.PRNGKey(seed))
    per_seq, num_pages = equal_page_budget(lanes, prompt_len, max_new_cap,
                                           page_size)
    out = {"num_pages": num_pages, "page_size": page_size}

    def fresh_requests():
        return make_zipf_requests(
            cfg.vocab, np.random.default_rng(seed), n_requests,
            prompt_len, zipf_a=zipf_a, max_new_cap=max_new_cap)

    prefill_fn = jax.jit(functools.partial(PM.prefill, cfg))

    def warmup():
        warmup_engine(cfg, params, lanes=lanes, num_pages=num_pages,
                      max_pages_per_seq=per_seq)

    if microbatches <= 1:
        pool = DevicePool.from_jax_devices(jax.devices()[:1],
                                           devices_per_node=1)
        rm = FlowOSRM(pool)

        def task(slice_):
            warmup()
            eng = ContinuousEngine(cfg, params, lanes=lanes,
                                   num_pages=num_pages,
                                   max_pages_per_seq=per_seq,
                                   slice_=slice_)
            out["continuous"] = timed_drain(eng, fresh_requests())
            out["hbm_bytes"] = slice_.hbm_bytes()
            if compare_static:
                stat = ContinuousEngine(cfg, params, lanes=lanes,
                                        num_pages=num_pages,
                                        max_pages_per_seq=per_seq,
                                        mode="static")
                out["static"] = timed_drain(stat, fresh_requests())
            return out

        spec = JobSpec(name="serve-continuous", tasks=[TaskSpec(
            name="serve", n_devices=1, mesh_shape=(1, 1),
            axis_names=("data", "model"), arch="paged-lm",
            task_fn=task)])
        rec = rm.wait(rm.submit(spec), timeout_s=3600)
        if rec.error:
            raise RuntimeError(rec.error)
        out["breakdown"] = rec.slices[0].breakdown()
    else:
        # disaggregated prefill: one sub-slice computes prompt KV, the
        # hop carries it onto the decode sub-slice, and the engine
        # ingests microbatch m while m+1 prefills (PR 2 overlap)
        pool = DevicePool.virtual(2, devices_per_node=1,
                                  kinds={(0, 1): "prefill",
                                         (1, 2): "decode"})
        dev = jax.devices()[0]
        for d in pool._devices:
            d.device = dev
        meta = MetaAccelerator(pool, link=link)
        if n_requests % microbatches:
            raise ValueError(f"requests={n_requests} must divide into "
                             f"microbatches={microbatches}")
        engine_box = {}

        def prefill_stage(slice_, payload):
            k, v, last = prefill_fn(params,
                                    jnp.asarray(payload["prompts"]))
            # batch axis first so the microbatch split/concat sees it
            return {"k": jnp.moveaxis(k, 1, 0), "v": jnp.moveaxis(v, 1, 0),
                    "last": last, "rid": payload["rid"]}

        def decode_stage(slice_, state):
            eng = engine_box["engine"]
            for i, rid in enumerate(np.asarray(state["rid"])):
                req = engine_box["reqs"][int(rid)]
                while None not in eng.lanes:
                    eng.step()          # decode overlaps later prefills
                eng.ingest_prefill(req, state["k"][i], state["v"][i],
                                   state["last"][i])
            return np.asarray(state["rid"])

        stages = [
            StageSpec(name="prefill", kind="prefill", n_devices=1,
                      mesh_shape=(1, 1), axis_names=("data", "model"),
                      stage_fn=prefill_stage),
            StageSpec(name="decode", kind="decode", n_devices=1,
                      mesh_shape=(1, 1), axis_names=("data", "model"),
                      stage_fn=decode_stage, donate_activations=False),
        ]
        slices = meta.allocate(stages)
        try:
            def pipeline_drain(reqs_list):
                engine = ContinuousEngine(
                    cfg, params, lanes=lanes, num_pages=num_pages,
                    max_pages_per_seq=per_seq, slice_=slices[1])
                engine_box["engine"] = engine
                engine_box["reqs"] = reqs_list
                payload = {
                    "prompts": np.stack([r.prompt for r in reqs_list]),
                    "rid": np.arange(n_requests, dtype=np.int32)}
                t0 = time.perf_counter()
                meta.run_pipeline(stages, slices, payload,
                                  microbatches=microbatches)
                stats = engine.run()    # drain in-flight decodes
                stats["seconds"] = time.perf_counter() - t0
                stats["tok_per_s"] = stats["generated_tokens"] / max(
                    stats["seconds"], 1e-9)
                return stats

            # untimed full-pipeline pass compiles everything the timed
            # run will hit — including the executables specialized on
            # the hop's committed shardings, which a hop-less warmup
            # cannot reach (PR 2's run_serving_pipelined does the same)
            pipeline_drain(fresh_requests())
            transfers_before = meta.transfer_totals()
            out["continuous"] = pipeline_drain(fresh_requests())
            transfers_after = meta.transfer_totals()
            out["hbm_bytes"] = slices[1].hbm_bytes()
            out["transfers"] = {
                k: transfers_after[k] - transfers_before[k]
                for k in transfers_after}
        finally:
            meta.release(slices)
        if compare_static:
            # the baseline has no prefill stage to disaggregate — it is
            # the same static drain as the slice path (warmed: its
            # uncommitted-sharding executable differs from the hop-fed
            # pipeline engines')
            warmup()
            stat = ContinuousEngine(cfg, params, lanes=lanes,
                                    num_pages=num_pages,
                                    max_pages_per_seq=per_seq,
                                    mode="static")
            out["static"] = timed_drain(stat, fresh_requests())
    return out


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--decode-len", type=int, default=16)
    p.add_argument("--microbatches", type=int, default=1,
                   help="k>1: disaggregated prefill/decode pipeline")
    p.add_argument("--link-gbytes", type=float, default=0.0,
                   help="emulated fabric bandwidth in gigaBYTES/s for "
                        "the pipelined path (0 = no emulation)")
    p.add_argument("--continuous", action="store_true",
                   help="paged-KV continuous-batching serving plane "
                        "(DESIGN.md §10)")
    p.add_argument("--requests", type=int, default=32,
                   help="continuous mode: workload size")
    p.add_argument("--lanes", type=int, default=8,
                   help="continuous mode: decode lanes")
    p.add_argument("--page-size", type=int, default=8,
                   help="continuous mode: tokens per KV page")
    args = p.parse_args()

    if args.continuous:
        link = (LinkModel(gbytes_per_s=args.link_gbytes)
                if args.link_gbytes > 0 else None)
        out = run_serving_continuous(
            n_requests=args.requests, lanes=args.lanes,
            prompt_len=args.prompt_len, page_size=args.page_size,
            microbatches=args.microbatches, link=link)
        c = out["continuous"]
        print(f"[serve] continuous batching: {c['tok_per_s']:.1f} tok/s "
              f"({c['generated_tokens']} tokens, {c['steps']} steps, "
              f"{c['preemptions']} preemptions, "
              f"{out['hbm_bytes'] / 1e6:.1f} MB KV pool)")
        if "static" in out:
            s = out["static"]
            print(f"[serve] static baseline:    {s['tok_per_s']:.1f} "
                  f"tok/s ({s['steps']} steps) -> "
                  f"{c['tok_per_s'] / s['tok_per_s']:.2f}x")
        if "transfers" in out:
            tr = out["transfers"]
            print(f"[serve] prefill fabric: {tr['hops']} hops, "
                  f"{tr['bytes'] / 1e6:.2f} MB, {tr['seconds']:.2f}s")
        return

    if args.arch is None:
        p.error("--arch is required unless --continuous")
    cfg = load_config(args.arch, args.smoke)
    if args.microbatches > 1:
        link = (LinkModel(gbytes_per_s=args.link_gbytes)
                if args.link_gbytes > 0 else None)
        out = run_serving_pipelined(
            cfg, batch=args.batch, prompt_len=args.prompt_len,
            decode_len=args.decode_len, microbatches=args.microbatches,
            link=link)
        tr = out["transfers"]
        print(f"[serve] {cfg.name} prefill/decode-disaggregated: "
              f"{out['decode_tok_per_s']:.1f} decode tok/s, "
              f"{out['e2e_tok_per_s']:.1f} end-to-end tok/s "
              f"(pipelined {out['pipelined_s']:.2f}s vs serial "
              f"{out['serial_s']:.2f}s, match={out['match']})")
        print(f"[serve] fabric: {tr['hops']} hops, "
              f"{tr['bytes'] / 1e6:.1f} MB, {tr['seconds']:.2f}s")
    else:
        out = run_serving(cfg, batch=args.batch,
                          prompt_len=args.prompt_len,
                          decode_len=args.decode_len)
        print(f"[serve] {cfg.name}: {out['decode_tok_per_s']:.1f} tok/s, "
              f"prefill {out['prefill_s']:.2f}s")
    print(f"[serve] sample tokens: {out['tokens'][0][:10].tolist()}")


if __name__ == "__main__":
    main()
