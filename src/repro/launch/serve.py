"""Serving driver: batched decode through a FlowOS-RM slice.

Implements the inference side of the paper's workload: a slice is
constructed for a serving job, requests are batched, prefill builds the KV
cache, and serve_step decodes token-by-token.

Usage (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --batch 4 --prompt-len 32 --decode-len 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pool import DevicePool
from repro.core.rm import FlowOSRM
from repro.core.job import JobSpec, TaskSpec
from repro.models.config import ShapeConfig
from repro.models.registry import get_model
from repro.launch.train import load_config
from repro.parallel.policy import sharding_policy
from repro.parallel.sharding import axis_rules
from repro.train import steps as S


def run_serving(cfg, *, batch: int, prompt_len: int, decode_len: int,
                mesh_shape=(1, 1), seed: int = 0):
    model = get_model(cfg)
    assert model.decode_step is not None, f"{cfg.name} has no decode path"
    max_len = prompt_len + decode_len
    shape = ShapeConfig("serve", max_len, batch, "decode")
    pool = DevicePool.from_jax_devices(
        jax.devices()[: int(np.prod(mesh_shape))], devices_per_node=1)
    rm = FlowOSRM(pool)
    out = {}

    def prepare(slice_):
        rules = sharding_policy(cfg, shape, slice_.mesh)
        serve_fn = jax.jit(S.make_serve_step(model, rules),
                           donate_argnums=(1,))
        return {"serve": serve_fn, "rules": rules}

    def task(slice_):
        exe = slice_.executable
        rules = exe["rules"]
        with slice_.mesh:
            key = jax.random.PRNGKey(seed)
            params = model.init(cfg, key)
            prompts = jax.random.randint(key, (batch, prompt_len), 0,
                                         cfg.vocab_size)
            cache = model.init_cache(cfg, batch, max_len)
            if cfg.family == "audio":
                from repro.models import whisper as W
                frames = jax.random.normal(
                    key, (batch, cfg.encoder_seq, cfg.d_model)) * 0.02
                with axis_rules(rules):
                    cache["cross"] = W.prefill_cross(
                        cfg, S.cast_params(cfg, params), frames)
            # prefill: feed prompt tokens one step at a time (simple path;
            # a fused prefill kernel is the production fast path)
            t0 = time.perf_counter()
            tok = prompts[:, :1]
            for t in range(prompt_len):
                logits, cache = exe["serve"](params, cache,
                                             prompts[:, t:t + 1])
            prefill_s = time.perf_counter() - t0
            # decode
            t0 = time.perf_counter()
            generated = []
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            for _ in range(decode_len):
                generated.append(tok)
                logits, cache = exe["serve"](params, cache, tok)
                tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            jax.block_until_ready(logits)
            decode_s = time.perf_counter() - t0
            out["tokens"] = np.asarray(jnp.concatenate(generated, axis=1))
            out["prefill_s"] = prefill_s
            out["decode_tok_per_s"] = batch * decode_len / decode_s
        return out

    spec = JobSpec(name=f"serve-{cfg.name}", tasks=[TaskSpec(
        name="serve", n_devices=int(np.prod(mesh_shape)),
        mesh_shape=tuple(mesh_shape), axis_names=("data", "model"),
        arch=cfg.name, prepare_fn=prepare, task_fn=task)])
    rec = rm.wait(rm.submit(spec), timeout_s=3600)
    if rec.error:
        raise RuntimeError(rec.error)
    out["breakdown"] = rec.slices[0].breakdown()
    return out


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--decode-len", type=int, default=16)
    args = p.parse_args()

    cfg = load_config(args.arch, args.smoke)
    out = run_serving(cfg, batch=args.batch, prompt_len=args.prompt_len,
                      decode_len=args.decode_len)
    print(f"[serve] {cfg.name}: {out['decode_tok_per_s']:.1f} tok/s, "
          f"prefill {out['prefill_s']:.2f}s")
    print(f"[serve] sample tokens: {out['tokens'][0][:10].tolist()}")


if __name__ == "__main__":
    main()
