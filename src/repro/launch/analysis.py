"""Roofline-term extraction from compiled dry-run artifacts.

Sources:
  * ``compiled.cost_analysis()`` -> HLO FLOPs and bytes accessed (per-device,
    post-SPMD-partitioning).
  * ``compiled.as_text()`` -> collective bytes: sum of output operand sizes
    of all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute ops (per-device program).

Hardware model (TPU v5e-class, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI. Terms are seconds-per-step *per chip*; the dominant term
is the roofline bottleneck.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link (we charge 1 link per hop)
DCN_BW = 6.25e9          # bytes/s per chip cross-pod (50 Gb/s NIC-class)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of all array shapes in an HLO shape string
    (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind output bytes in a (per-device) HLO module.

    Start/done pairs (async collectives) are counted once via the -start op;
    plain (sync) ops are counted directly.
    """
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        lhs, rhs = line.split("=", 1)
        rhs = rhs.strip()
        m = re.match(r"^(\([^)]*\)|[\w\[\],]+)\s+([\w-]+)", rhs)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        base = op
        for suffix in ("-start", "-done"):
            if base.endswith(suffix):
                base = base[: -len(suffix)]
        if base not in _COLLECTIVES:
            continue
        if op.endswith("-done"):
            continue  # counted at -start
        out[base] += _shape_bytes(shape_str)
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    hlo_flops: float            # per device
    hlo_bytes: float            # per device (bytes accessed)
    coll_bytes: Dict[str, int]  # per device, by kind
    cross_pod: bool
    model_flops: float          # 6*N*D (or 6*N_active*D) global
    peak_memory: Optional[int] = None  # per device

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / HBM_BW

    dcn_bytes: float = 0.0  # pod-spanning subset of collective bytes

    @property
    def collective_s(self) -> float:
        total = sum(self.coll_bytes.values())
        if not self.cross_pod:
            return total / ICI_BW
        ici = max(total - self.dcn_bytes, 0.0)
        return ici / ICI_BW + self.dcn_bytes / DCN_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step estimate: max of the three terms (perfect
        overlap assumption)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs * n_devices) — remat/redundancy waste."""
        total_hlo = self.hlo_flops * self.n_devices
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline step estimate."""
        if self.step_time_s == 0:
            return 0.0
        return (self.model_flops
                / (self.n_devices * PEAK_FLOPS * self.step_time_s))

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "n_devices": self.n_devices,
            "hlo_flops_per_dev": self.hlo_flops,
            "hlo_bytes_per_dev": self.hlo_bytes,
            "coll_bytes_per_dev": self.coll_bytes,
            "dcn_bytes_per_dev": self.dcn_bytes,
            "cross_pod": self.cross_pod,
            "model_flops": self.model_flops,
            "peak_memory_per_dev": self.peak_memory,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_time_s": self.step_time_s,
            "useful_flops_fraction": self.useful_flops_fraction,
            "mfu": self.mfu,
        }


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D for training (D = tokens), 2*N*D for inference
    fwd; decode D = global_batch tokens per step."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # one token per sequence per step
    return 2.0 * n * tokens


def memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        out["peak_bytes"] = (out.get("argument_size_in_bytes", 0)
                             + out.get("temp_size_in_bytes", 0)
                             + out.get("output_size_in_bytes", 0)
                             - out.get("alias_size_in_bytes", 0))
    return out
