import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

DOC = """Kernel-substitution roofline (§Perf methodology).

The dry-run lowers the pure-jnp model, whose attention core necessarily
materializes (bq, bkv) score blocks at HLO fusion boundaries — traffic the
validated Pallas flash kernel keeps in VMEM on the real TPU deployment.
This tool produces the *kernel-adjusted* roofline for a cell:

  1. lower the cell normally                  -> total terms
  2. lower with cfg.attn_stub=True            -> non-attention terms
  3. attention-core traffic = (1) - (2); replace it with the analytic
     kernel traffic (Q, K, V, O streamed once per pass; passes: fwd=1,
     train adds ~2.5x for the recompute+grad passes)
  4. adjusted memory term = stub memory + kernel traffic / HBM_BW
     (FLOPs and collectives keep the measured values)

Usage:
  PYTHONPATH=src python -m repro.launch.perf --arch qwen2.5-3b \
      --shape train_4k [--multi-pod]
"""

import argparse
import json

from repro.launch.analysis import HBM_BW
from repro.models import SHAPES
from repro.models.registry import get_config


def flash_kernel_traffic(cfg, shape, n_devices: int, strategy: str) -> float:
    """Analytic per-device HBM bytes of the Pallas flash kernel for all
    layers and passes of one step."""
    B, S = shape.global_batch, shape.seq_len
    if shape.is_decode:
        S_q = 1
        S_kv = shape.seq_len
    else:
        S_q = S_kv = S
    Hq, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cfg.family in ("ssm",):
        return 0.0
    n_attn_layers = (cfg.n_layers // cfg.attn_every if cfg.is_hybrid
                     else cfg.n_layers)
    if cfg.is_encdec:
        n_attn_layers = cfg.n_layers + cfg.n_encoder_layers
    # bytes per pass per layer (global): q + o at S_q, k + v at S_kv, bf16
    per_layer = (2 * B * S_q * Hq * D + 2 * B * S_kv * Hkv * D) * 2
    passes = 3.5 if shape.kind == "train" else 1.0
    repl = 1.0
    if strategy == "replicated_attn":
        repl = 16.0  # attention replicated over the idle model axis
    return per_layer * n_attn_layers * passes * repl / n_devices


def kernel_adjusted(arch: str, shape_name: str, multi_pod: bool = False):
    from repro.launch.dryrun import dryrun_cell

    base = dryrun_cell(arch, shape_name, multi_pod, verbose=False)
    stub = dryrun_cell(arch, shape_name, multi_pod, verbose=False,
                       cfg_overrides={"attn_stub": True})
    cfg = get_config(arch)
    shape = SHAPES[shape_name]

    attn_core_bytes = max(base["hlo_bytes_per_dev"]
                          - stub["hlo_bytes_per_dev"], 0.0)
    kernel_bytes = flash_kernel_traffic(cfg, shape, base["n_devices"],
                                        base.get("strategy", "tp"))
    adj_bytes = stub["hlo_bytes_per_dev"] + kernel_bytes
    adj_memory_s = adj_bytes / HBM_BW
    step = max(base["compute_s"], adj_memory_s, base["collective_s"])
    mfu = base["model_flops"] / (base["n_devices"] * 197e12 * step)
    out = dict(base)
    out.update({
        "attn_core_bytes_per_dev": attn_core_bytes,
        "kernel_bytes_per_dev": kernel_bytes,
        "adj_memory_s": adj_memory_s,
        "adj_step_time_s": step,
        "adj_mfu": mfu,
        "adj_dominant": max(
            (("compute", base["compute_s"]), ("memory", adj_memory_s),
             ("collective", base["collective_s"])), key=lambda kv: kv[1])[0],
    })
    return out


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--shape", required=True)
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--out", type=str, default=None)
    args = p.parse_args()
    rec = kernel_adjusted(args.arch, args.shape, args.multi_pod)
    print(f"[perf] {args.arch} x {args.shape} x {rec['mesh']}:")
    print(f"  baseline: compute {rec['compute_s']*1e3:.1f}ms "
          f"memory {rec['memory_s']*1e3:.1f}ms "
          f"collective {rec['collective_s']*1e3:.1f}ms "
          f"-> {rec['dominant']}-bound, MFU {rec['mfu']:.1%}")
    print(f"  attention-core traffic {rec['attn_core_bytes_per_dev']/1e9:.1f}"
          f" GB/dev -> kernel {rec['kernel_bytes_per_dev']/1e9:.1f} GB/dev")
    print(f"  kernel-adjusted: memory {rec['adj_memory_s']*1e3:.1f}ms "
          f"-> {rec['adj_dominant']}-bound, MFU {rec['adj_mfu']:.1%}")
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
