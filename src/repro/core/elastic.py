"""Elasticity, fault tolerance and straggler mitigation.

At 1000+ nodes, failures are continuous background noise, not exceptions.
The controller implements the three mechanisms a production fleet needs:

1. **Failure recovery** — on device failure FlowOS-RM shrinks the slice to
   the largest feasible mesh from the remaining healthy pool, and the job
   resumes from the last checkpoint (state re-shards onto the new mesh via
   ``CheckpointManager.restore(shardings=...)``).
2. **Straggler mitigation** — per-node step-time EWMAs; a node persistently
   slower than the median by ``straggler_factor`` for ``patience`` steps is
   evicted (rebuilt slice excludes it). This is the disaggregated-pool
   advantage the paper argues for: swap a slow accelerator, keep the node.
3. **Elastic rescale** — when the pool frees up, a job below its preferred
   size can grow at the next checkpoint boundary.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.pool import DevicePool, Lease


@dataclasses.dataclass
class ElasticDecision:
    action: str  # "none" | "shrink" | "evict" | "grow"
    n_devices: Optional[int] = None
    evict_nodes: Tuple[int, ...] = ()
    reason: str = ""


def largest_feasible(n_healthy: int, min_devices: int = 1) -> int:
    """Largest power-of-two slice size <= n_healthy (mesh-factorable)."""
    if n_healthy < min_devices:
        return 0
    return 2 ** int(math.floor(math.log2(n_healthy)))


def mesh_shape_for(n: int, model_parallel: int = 1) -> Tuple[int, int]:
    """(data, model) factorization for n devices."""
    model = min(model_parallel, n)
    while n % model != 0:
        model //= 2
    return (n // model, max(model, 1))


class ElasticController:
    def __init__(self, pool: DevicePool, straggler_factor: float = 1.5,
                 patience: int = 3, ewma: float = 0.5):
        self.pool = pool
        self.straggler_factor = straggler_factor
        self.patience = patience
        self.ewma = ewma
        self._node_times: Dict[int, float] = {}
        self._slow_streak: Dict[int, int] = {}

    # -- straggler detection ------------------------------------------------
    def record_step(self, per_node_seconds: Dict[int, float]):
        for node, t in per_node_seconds.items():
            prev = self._node_times.get(node, t)
            self._node_times[node] = (1 - self.ewma) * prev + self.ewma * t

    def stragglers(self) -> List[int]:
        if len(self._node_times) < 2:
            return []
        times = sorted(self._node_times.values())
        median = times[len(times) // 2]
        out = []
        for node, t in self._node_times.items():
            if t > self.straggler_factor * median:
                self._slow_streak[node] = self._slow_streak.get(node, 0) + 1
            else:
                self._slow_streak[node] = 0
            if self._slow_streak.get(node, 0) >= self.patience:
                out.append(node)
        return out

    # -- decisions ------------------------------------------------------------
    def check(self, lease: Lease, preferred_devices: int) -> ElasticDecision:
        """Called at step/checkpoint boundaries by the training driver."""
        failed = self.pool.failed_in_lease(lease)
        if failed:
            healthy = lease.n - len(failed)
            target = largest_feasible(healthy)
            return ElasticDecision(
                action="shrink", n_devices=target,
                reason=f"{len(failed)} device(s) failed in lease")
        slow = self.stragglers()
        if slow:
            lease_nodes = lease.nodes
            evict = tuple(n for n in slow if n in lease_nodes)
            if evict:
                return ElasticDecision(
                    action="evict", evict_nodes=evict,
                    n_devices=largest_feasible(
                        lease.n - sum(1 for d in lease.devices
                                      if d.node in evict)),
                    reason=f"straggler node(s) {evict}")
        if lease.n < preferred_devices:
            extra = self.pool.free_count()  # O(1) from the free-run index
            grown = largest_feasible(lease.n + extra)
            if grown > lease.n and grown <= preferred_devices:
                return ElasticDecision(
                    action="grow", n_devices=grown,
                    reason="pool freed up; grow toward preferred size")
        return ElasticDecision(action="none")

    # -- slice rebuild ----------------------------------------------------------
    def rebuild(self, slice_, decision: ElasticDecision,
                model_parallel: int = 1):
        """Release the old lease and build a replacement slice. The caller
        restores the latest checkpoint onto the new mesh's shardings."""
        from repro.core.slice import Slice

        pool = slice_.pool
        if slice_.lease is not None:
            pool.release(slice_.lease)
            slice_.lease = None
        if not decision.n_devices:
            raise RuntimeError("no feasible slice size after failure")
        shape = mesh_shape_for(decision.n_devices, model_parallel)
        new = Slice(name=slice_.name + "+rebuilt", pool=pool,
                    n_devices=decision.n_devices, mesh_shape=shape,
                    axis_names=("data", "model"), kind=slice_.kind)
        new.attach_device()
        new.launch_machine()
        # reset straggler state for evicted nodes
        for node in decision.evict_nodes:
            self._node_times.pop(node, None)
            self._slow_streak.pop(node, None)
        return new
