"""FlowOS-RM: the disaggregated resource manager (paper §4).

Cooperates with a cluster-RM-shaped execution layer (thread-per-job here,
Mesos in the paper — the contract is identical: co-allocate, then launch
tasks on slice members). Scheduling is FIFO (paper Fig. 5) with optional
backfill; every allocation goes through the DevicePool's contiguity-aware
placement.

The event log (time, job, phase) is what benchmarks/sharing.py renders into
the Fig. 5 reproduction.
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import Any, Dict, List, Optional

from repro.core.job import JobRecord, JobSpec, JobStatus, TaskSpec
from repro.core.pool import AllocationError, DevicePool
from repro.core.slice import Slice


class FlowOSRM:
    def __init__(self, pool: DevicePool, backfill: bool = False,
                 simulate_boot_s: float = 0.0):
        self.pool = pool
        self.backfill = backfill
        self.simulate_boot_s = simulate_boot_s
        self._lock = threading.RLock()
        self._job_counter = itertools.count(1)
        self._queue: List[JobRecord] = []
        self._jobs: Dict[int, JobRecord] = {}
        self._threads: Dict[int, threading.Thread] = {}
        self.events: List[tuple] = []
        self._t0 = time.perf_counter()

    # -- REST-like API ----------------------------------------------------
    def submit(self, spec: JobSpec) -> int:
        with self._lock:
            rec = JobRecord(job_id=next(self._job_counter), spec=spec,
                            submit_time=self._now())
            self._queue.append(rec)
            self._jobs[rec.job_id] = rec
            self._log(rec, "submitted")
            return rec.job_id

    def submit_dict(self, d: dict) -> int:
        return self.submit(JobSpec.from_dict(d))

    def status(self, job_id: int) -> dict:
        with self._lock:
            return self._jobs[job_id].to_dict()

    def cancel(self, job_id: int) -> bool:
        with self._lock:
            rec = self._jobs[job_id]
            if rec.status == JobStatus.QUEUED:
                self._queue.remove(rec)
                rec.status = JobStatus.CANCELLED
                self._log(rec, "cancelled")
                return True
            return False

    def pool_utilization(self) -> float:
        return self.pool.utilization()

    # -- scheduling --------------------------------------------------------
    def schedule_once(self) -> int:
        """One FIFO pass; returns number of jobs dispatched."""
        dispatched = 0
        with self._lock:
            pending = list(self._queue)
        for rec in pending:
            if self._try_dispatch(rec):
                dispatched += 1
            elif not self.backfill:
                break  # strict FIFO: head-of-line blocks
        return dispatched

    def _try_dispatch(self, rec: JobRecord) -> bool:
        with self._lock:
            if rec.status != JobStatus.QUEUED:
                return False
            need = {}
            for t in rec.spec.tasks:
                need[t.kind] = need.get(t.kind, 0) + t.n_devices
            for kind, n in need.items():
                if not self.pool.can_allocate(n, kind):
                    return False
            rec.status = JobStatus.ALLOCATING
            self._queue.remove(rec)
            slices = []
            try:
                for t in rec.spec.tasks:
                    s = Slice(name=f"{rec.spec.name}/{t.name}",
                              pool=self.pool, n_devices=t.n_devices,
                              mesh_shape=t.mesh_shape,
                              axis_names=t.axis_names, kind=t.kind)
                    s.attach_device()
                    slices.append(s)
            except AllocationError:
                for s in slices:
                    if s.lease is not None:
                        self.pool.release(s.lease)
                rec.status = JobStatus.QUEUED
                self._queue.insert(0, rec)
                return False
            rec.slices = slices
            rec.status = JobStatus.RUNNING
            rec.start_time = self._now()
            self._log(rec, "started")
        th = threading.Thread(target=self._run_job, args=(rec,), daemon=True)
        with self._lock:
            self._threads[rec.job_id] = th
        th.start()
        return True

    def _run_job(self, rec: JobRecord):
        try:
            results = []
            for t, s in zip(rec.spec.tasks, rec.slices):
                s.launch_machine(simulate_boot_s=self.simulate_boot_s)
                self._log(rec, f"{t.name}:launched")
                s.prepare_task(t.prepare_fn)
                self._log(rec, f"{t.name}:prepared")
                results.append(s.launch_task(t.task_fn))
                self._log(rec, f"{t.name}:finished")
                s.detach_device()
                s.destroy_machine()
            rec.result = results if len(results) > 1 else results[0]
            rec.status = JobStatus.DONE
        except BaseException as e:  # noqa: BLE001 — job isolation
            rec.error = f"{type(e).__name__}: {e}"
            rec.status = JobStatus.FAILED
            for s in rec.slices:
                if s.lease is not None:
                    self.pool.release(s.lease)
                    s.lease = None
        finally:
            rec.end_time = self._now()
            self._log(rec, rec.status.value)

    # -- drive to completion -----------------------------------------------
    def run_until_idle(self, poll_s: float = 0.005, timeout_s: float = 600.0):
        deadline = time.perf_counter() + timeout_s
        while time.perf_counter() < deadline:
            self.schedule_once()
            with self._lock:
                busy = bool(self._queue) or any(
                    r.status in (JobStatus.RUNNING, JobStatus.ALLOCATING)
                    for r in self._jobs.values())
            if not busy:
                return
            time.sleep(poll_s)
        raise TimeoutError("jobs did not finish before timeout")

    def wait(self, job_id: int, timeout_s: float = 600.0) -> JobRecord:
        deadline = time.perf_counter() + timeout_s
        while time.perf_counter() < deadline:
            self.schedule_once()
            rec = self._jobs[job_id]
            if rec.status in (JobStatus.DONE, JobStatus.FAILED,
                              JobStatus.CANCELLED):
                th = self._threads.get(job_id)
                if th is not None:
                    th.join(timeout=timeout_s)
                return rec
            time.sleep(0.005)
        raise TimeoutError(f"job {job_id} did not finish")

    # -- internals -----------------------------------------------------------
    def _now(self) -> float:
        return time.perf_counter() - self._t0

    def _log(self, rec: JobRecord, event: str):
        self.events.append((self._now(), rec.spec.name, event))
