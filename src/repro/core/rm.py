"""FlowOS-RM: the disaggregated resource manager (paper §4).

Cooperates with a cluster-RM-shaped execution layer (thread-per-job here,
Mesos in the paper — the contract is identical: co-allocate, then launch
tasks on slice members). Scheduling is FIFO (paper Fig. 5) with optional
backfill; every allocation goes through the DevicePool's contiguity-aware
placement (free-run index, DESIGN.md §3).

The control loop is **event-driven** (DESIGN.md §4): a ``threading.Condition``
is notified on job submission, job completion, cancellation, and pool
capacity return (via ``DevicePool.add_release_listener``), so
``run_until_idle`` / ``wait`` block on condition-variable wakeups instead of
sleep-polling — at thousands of jobs the 5ms poll of the seed implementation
dominates scheduler latency.

The event log (time, job, phase) is what benchmarks/sharing.py renders into
the Fig. 5 reproduction.
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import Any, Dict, Iterable, List, Optional

from repro.core.job import JobRecord, JobSpec, JobStatus, TaskSpec
from repro.core.pool import AllocationError, DevicePool
from repro.core.slice import Slice

_TERMINAL = (JobStatus.DONE, JobStatus.FAILED, JobStatus.CANCELLED)


class FlowOSRM:
    def __init__(self, pool: DevicePool, backfill: bool = False,
                 simulate_boot_s: float = 0.0):
        self.pool = pool
        self.backfill = backfill
        self.simulate_boot_s = simulate_boot_s
        self._lock = threading.RLock()
        # Wakeup channel for run_until_idle/wait. Deliberately NOT tied to
        # self._lock: _wakeup is invoked from DevicePool's release fan-out,
        # where the calling thread may hold *another* RM's lock (shared
        # pool, several RMs). The wake lock is a leaf — nothing is acquired
        # while holding it — so the fan-out can never form a lock cycle.
        # _wake_seq makes the check-then-wait race-free: every event bumps
        # it, and waiters only sleep if it is unchanged since before their
        # state check.
        self._wake_cond = threading.Condition(threading.Lock())
        self._wake_seq = 0
        self._job_counter = itertools.count(1)
        self._queue: List[JobRecord] = []
        self._jobs: Dict[int, JobRecord] = {}
        self._threads: Dict[int, threading.Thread] = {}
        self.events: List[tuple] = []
        self._t0 = time.perf_counter()
        # capacity returning to the pool (lease release / repair) is a
        # scheduling event: wake any thread blocked in run_until_idle/wait
        pool.add_release_listener(self._wakeup)

    def _wakeup(self):
        with self._wake_cond:
            self._wake_seq += 1
            self._wake_cond.notify_all()

    def close(self):
        """Unregister from the pool. An RM that is not closed stays
        referenced by the pool's listener list for the pool's lifetime —
        call this (or use the RM as a context manager) when creating many
        RMs against one long-lived pool."""
        self.pool.remove_release_listener(self._wakeup)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- REST-like API ----------------------------------------------------
    def submit(self, spec: JobSpec) -> int:
        return self.submit_many([spec])[0]

    def submit_many(self, specs: Iterable[JobSpec]) -> List[int]:
        """Batch submission: one lock round-trip and one scheduler wakeup
        for the whole batch (amortizes lock traffic for 1000-job loads)."""
        with self._lock:
            ids = []
            for spec in specs:
                rec = JobRecord(job_id=next(self._job_counter), spec=spec,
                                submit_time=self._now())
                self._queue.append(rec)
                self._jobs[rec.job_id] = rec
                self._log(rec, "submitted")
                ids.append(rec.job_id)
        self._wakeup()
        return ids

    def submit_dict(self, d: dict) -> int:
        return self.submit(JobSpec.from_dict(d))

    def status(self, job_id: int) -> dict:
        with self._lock:
            return self._jobs[job_id].to_dict()

    def cancel(self, job_id: int) -> bool:
        with self._lock:
            rec = self._jobs[job_id]
            if rec.status == JobStatus.QUEUED:
                self._queue.remove(rec)
                rec.status = JobStatus.CANCELLED
                self._log(rec, "cancelled")
                cancelled = True
            else:
                cancelled = False
        if cancelled:
            self._wakeup()
        return cancelled

    def pool_utilization(self) -> float:
        return self.pool.utilization()

    # -- scheduling --------------------------------------------------------
    def schedule_once(self) -> int:
        """One FIFO pass; returns number of jobs dispatched."""
        dispatched = 0
        with self._lock:
            pending = list(self._queue)
        for rec in pending:
            if self._try_dispatch(rec):
                dispatched += 1
            elif not self.backfill:
                break  # strict FIFO: head-of-line blocks
        return dispatched

    def _try_dispatch(self, rec: JobRecord) -> bool:
        with self._lock:
            if rec.status != JobStatus.QUEUED:
                return False
            need: Dict[Optional[str], int] = {}
            for t in rec.spec.tasks:
                need[t.kind] = need.get(t.kind, 0) + t.n_devices
            # one O(#kinds) feasibility check against the free-run index
            # (the seed re-filtered the whole fleet once per kind)
            if not self.pool.can_allocate_many(need):
                return False
            rec.status = JobStatus.ALLOCATING
            self._queue.remove(rec)
            slices = []
            try:
                for t in rec.spec.tasks:
                    s = Slice(name=f"{rec.spec.name}/{t.name}",
                              pool=self.pool, n_devices=t.n_devices,
                              mesh_shape=t.mesh_shape,
                              axis_names=t.axis_names, kind=t.kind,
                              prefer_contiguous=t.prefer_contiguous)
                    s.attach_device()
                    slices.append(s)
            except AllocationError:
                for s in slices:
                    if s.lease is not None:
                        self.pool.release(s.lease)
                rec.status = JobStatus.QUEUED
                self._queue.insert(0, rec)
                return False
            rec.slices = slices
            rec.status = JobStatus.RUNNING
            rec.start_time = self._now()
            self._log(rec, "started")
        th = threading.Thread(target=self._run_job, args=(rec,), daemon=True)
        with self._lock:
            self._threads[rec.job_id] = th
        th.start()
        return True

    def _run_job(self, rec: JobRecord):
        try:
            results = []
            for t, s in zip(rec.spec.tasks, rec.slices):
                s.launch_machine(simulate_boot_s=self.simulate_boot_s)
                self._log(rec, f"{t.name}:launched")
                s.prepare_task(t.prepare_fn)
                self._log(rec, f"{t.name}:prepared")
                results.append(s.launch_task(t.task_fn))
                self._log(rec, f"{t.name}:finished")
                s.detach_device()
                s.destroy_machine()
            rec.result = results if len(results) > 1 else results[0]
            rec.status = JobStatus.DONE
        except BaseException as e:  # noqa: BLE001 — job isolation
            rec.error = f"{type(e).__name__}: {e}"
            rec.status = JobStatus.FAILED
            for s in rec.slices:
                if s.lease is not None:
                    self.pool.release(s.lease)
                    s.lease = None
        finally:
            rec.end_time = self._now()
            self._log(rec, rec.status.value)
            self._wakeup()

    # -- drive to completion -----------------------------------------------
    def _busy(self) -> bool:
        return bool(self._queue) or any(
            r.status in (JobStatus.RUNNING, JobStatus.ALLOCATING)
            for r in self._jobs.values())

    def run_until_idle(self, poll_s: Optional[float] = None,
                       timeout_s: float = 600.0):
        """Schedule until the queue drains and all jobs finish.

        Event-driven: blocks on the scheduler condition between passes —
        woken by submissions, completions, and pool releases. ``poll_s`` is
        kept for API compatibility; it no longer drives a sleep loop.
        """
        del poll_s  # legacy polling interval — wakeups are event-driven now
        deadline = time.perf_counter() + timeout_s
        while True:
            with self._wake_cond:
                seq = self._wake_seq
            self.schedule_once()
            with self._lock:
                busy = self._busy()
            if not busy:
                return
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                raise TimeoutError("jobs did not finish before timeout")
            with self._wake_cond:
                # an event between the seq snapshot and here bumped the
                # counter — skip the wait and re-check instead of sleeping
                if self._wake_seq == seq:
                    self._wake_cond.wait(remaining)

    def wait(self, job_id: int, timeout_s: float = 600.0) -> JobRecord:
        deadline = time.perf_counter() + timeout_s
        while True:
            with self._wake_cond:
                seq = self._wake_seq
            self.schedule_once()
            with self._lock:
                rec = self._jobs[job_id]
                done = rec.status in _TERMINAL
                th = self._threads.get(job_id)
            if done:
                break
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                raise TimeoutError(f"job {job_id} did not finish")
            with self._wake_cond:
                if self._wake_seq == seq:
                    self._wake_cond.wait(remaining)
        # join with the *remaining* deadline budget — not the full timeout
        # again — so wait() blocks at most ~timeout_s in total
        if th is not None:
            th.join(timeout=max(0.0, deadline - time.perf_counter()))
        return rec

    # -- internals -----------------------------------------------------------
    def _now(self) -> float:
        return time.perf_counter() - self._t0

    def _log(self, rec: JobRecord, event: str):
        self.events.append((self._now(), rec.spec.name, event))
