"""FlowOS-RM: the disaggregated resource manager (paper §4).

Cooperates with a cluster-RM-shaped execution layer (thread-per-job here,
Mesos in the paper — the contract is identical: co-allocate, then launch
tasks on slice members). Every allocation goes through the DevicePool's
contiguity-aware placement (free-run index, DESIGN.md §3).

Scheduling policy (DESIGN.md §9): strict-priority pop with anti-starvation
aging — the queue is ordered by ``effective priority = base priority +
min(aging_cap, waited / aging_s)``, ties broken FIFO — with gang admission
(a multi-task job is admitted atomically or not at all), cooperative
preemption (a high-priority request blocked only by preemptible leases asks
those jobs to checkpoint and yield), and an idle-time defragmentation pass
that relocates small leases to re-coalesce large free runs. With every job
at the default priority the policy degenerates to the seed's FIFO(+optional
backfill), so the Fig. 5 reproduction is unchanged.

The control loop is **event-driven** (DESIGN.md §4): a ``threading.Condition``
is notified on job submission, job completion, cancellation, and pool
capacity return (via ``DevicePool.add_release_listener``), so
``run_until_idle`` / ``wait`` block on condition-variable wakeups instead of
sleep-polling — at thousands of jobs the 5ms poll of the seed implementation
dominates scheduler latency.

The event log (time, job, phase) is what benchmarks/sharing.py renders into
the Fig. 5 reproduction.
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import Dict, Iterable, List, Optional

from repro.core.job import JobRecord, JobSpec, JobStatus, Preempted
from repro.core.pool import AllocationError, DevicePool
from repro.core.slice import Slice

_TERMINAL = (JobStatus.DONE, JobStatus.FAILED, JobStatus.CANCELLED)


class FlowOSRM:
    # checkpoint-manager factory; a class attribute so scheduler-only
    # deployments (and tests) can swap it without importing jax up front
    _ckpt_cls = None

    def __init__(self, pool: DevicePool, backfill: bool = False,
                 simulate_boot_s: float = 0.0, *,
                 preempt: bool = True,
                 aging_s: float = 30.0, aging_cap: int = 10,
                 auto_defrag: bool = False, frag_threshold: float = 0.5,
                 defrag_max_moves: int = 4, relocation_limit: int = 2):
        self.pool = pool
        self.backfill = backfill
        self.simulate_boot_s = simulate_boot_s
        # policy knobs (DESIGN.md §9)
        self.preempt = preempt              # allow preempting for priority
        self.aging_s = aging_s              # seconds per +1 aged priority
        self.aging_cap = aging_cap          # max aging boost (keeps a real
                                            # priority gap unbridgeable)
        self.auto_defrag = auto_defrag      # compaction on idle passes
        self.frag_threshold = frag_threshold
        self.defrag_max_moves = defrag_max_moves
        self.relocation_limit = relocation_limit  # per-job defrag moves
        self._lock = threading.RLock()
        # Wakeup channel for run_until_idle/wait. Deliberately NOT tied to
        # self._lock: _wakeup is invoked from DevicePool's release fan-out,
        # where the calling thread may hold *another* RM's lock (shared
        # pool, several RMs). The wake lock is a leaf — nothing is acquired
        # while holding it — so the fan-out can never form a lock cycle.
        # _wake_seq makes the check-then-wait race-free: every event bumps
        # it, and waiters only sleep if it is unchanged since before their
        # state check.
        self._wake_cond = threading.Condition(threading.Lock())
        self._wake_seq = 0
        self._job_counter = itertools.count(1)
        self._queue: List[JobRecord] = []
        self._jobs: Dict[int, JobRecord] = {}
        self._threads: Dict[int, threading.Thread] = {}
        self.events: List[tuple] = []
        self._t0 = time.perf_counter()
        # capacity returning to the pool (lease release / repair) is a
        # scheduling event: wake any thread blocked in run_until_idle/wait
        pool.add_release_listener(self._wakeup)

    def _wakeup(self):
        with self._wake_cond:
            self._wake_seq += 1
            self._wake_cond.notify_all()

    def close(self):
        """Unregister from the pool. An RM that is not closed stays
        referenced by the pool's listener list for the pool's lifetime —
        call this (or use the RM as a context manager) when creating many
        RMs against one long-lived pool."""
        self.pool.remove_release_listener(self._wakeup)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- REST-like API ----------------------------------------------------
    def submit(self, spec: JobSpec) -> int:
        return self.submit_many([spec])[0]

    def submit_many(self, specs: Iterable[JobSpec]) -> List[int]:
        """Batch submission: one lock round-trip and one scheduler wakeup
        for the whole batch (amortizes lock traffic for 1000-job loads)."""
        with self._lock:
            ids = []
            for spec in specs:
                rec = JobRecord(job_id=next(self._job_counter), spec=spec,
                                submit_time=self._now())
                self._queue.append(rec)
                self._jobs[rec.job_id] = rec
                self._log(rec, "submitted")
                ids.append(rec.job_id)
        self._wakeup()
        return ids

    def submit_dict(self, d: dict) -> int:
        return self.submit(JobSpec.from_dict(d))

    def status(self, job_id: int) -> dict:
        with self._lock:
            return self._jobs[job_id].to_dict()

    def jobs(self) -> List[dict]:
        """Status dicts for every job the RM has seen (the REST-like
        list endpoint; what benchmarks aggregate over)."""
        with self._lock:
            return [r.to_dict() for r in self._jobs.values()]

    def quiescent(self) -> bool:
        """True when no job is queued or mid-preemption (requested or
        PREEMPTING) — the settle condition defrag/preemption drivers
        poll between scheduler passes."""
        with self._lock:
            return not any(
                r.preempt_requested
                or r.status in (JobStatus.QUEUED, JobStatus.PREEMPTING)
                for r in self._jobs.values())

    def cancel(self, job_id: int) -> bool:
        with self._lock:
            rec = self._jobs[job_id]
            if rec.status == JobStatus.QUEUED:
                self._queue.remove(rec)
                rec.status = JobStatus.CANCELLED
                self._log(rec, "cancelled")
                cancelled = True
            else:
                cancelled = False
        if cancelled:
            self._wakeup()
        return cancelled

    def pool_utilization(self) -> float:
        return self.pool.utilization()

    # -- scheduling --------------------------------------------------------
    def _effective_priority(self, rec: JobRecord, now: float) -> int:
        """Base priority plus the anti-starvation aging boost: +1 per
        ``aging_s`` seconds waited, capped at ``aging_cap`` so a base-
        priority gap wider than the cap is never bridged by waiting (a
        max-priority job cannot be overtaken by an aged low-priority
        one)."""
        boost = 0
        if self.aging_s > 0:
            boost = min(self.aging_cap,
                        int((now - rec.submit_time) / self.aging_s))
        return rec.spec.effective_priority + boost

    def schedule_once(self) -> int:
        """One strict-priority pass (aged priority desc, then FIFO);
        returns number of jobs dispatched. Without backfill the highest-
        priority blocked job blocks everything behind it; with backfill
        lower-priority jobs may slip past it into leftover capacity. If
        the head stays blocked, try to free its capacity by cooperatively
        preempting lower-priority preemptible jobs."""
        dispatched = 0
        with self._lock:
            now = self._now()
            pending = sorted(
                self._queue,
                key=lambda r: (-self._effective_priority(r, now), r.job_id))
        blocked: Optional[JobRecord] = None
        for rec in pending:
            if self._try_dispatch(rec):
                dispatched += 1
                continue
            if blocked is None and rec.status == JobStatus.QUEUED:
                blocked = rec
            if not self.backfill:
                break  # strict priority: head-of-line blocks
        if blocked is not None and self.preempt:
            self._preempt_for(blocked)
        return dispatched

    def _try_dispatch(self, rec: JobRecord) -> bool:
        with self._lock:
            if rec.status != JobStatus.QUEUED:
                return False
            need: Dict[Optional[str], int] = {}
            for t in rec.spec.tasks:
                need[t.kind] = need.get(t.kind, 0) + t.n_devices
            # one O(#kinds) feasibility check against the free-run index
            # (the seed re-filtered the whole fleet once per kind)
            if not self.pool.can_allocate_many(need):
                return False
            rec.status = JobStatus.ALLOCATING
            self._queue.remove(rec)
            # gang admission: all task slices attach under the RM lock or
            # none do — a shared-pool race that steals capacity mid-gang
            # rolls the whole job back to QUEUED with every lease returned
            slices = []
            try:
                for t in rec.spec.tasks:
                    s = Slice(name=f"{rec.spec.name}/{t.name}",
                              pool=self.pool, n_devices=t.n_devices,
                              mesh_shape=t.mesh_shape,
                              axis_names=t.axis_names, kind=t.kind,
                              prefer_contiguous=t.prefer_contiguous)
                    s.attach_device()
                    slices.append(s)
            except AllocationError:
                for s in slices:
                    if s.lease is not None:
                        self.pool.release(s.lease)
                rec.status = JobStatus.QUEUED
                self._queue.insert(0, rec)
                return False
            rec.slices = slices
            rec.status = JobStatus.RUNNING
            rec.start_time = self._now()
            self._log(rec, "started")
        th = threading.Thread(target=self._run_job, args=(rec,), daemon=True)
        with self._lock:
            self._threads[rec.job_id] = th
        th.start()
        return True

    # -- cooperative preemption (DESIGN.md §9) -----------------------------
    def _held_by_kind(self, rec: JobRecord) -> Dict[str, int]:
        held: Dict[str, int] = {}
        for s in rec.slices:
            # snapshot: the job thread nulls s.lease on detach without
            # taking the RM lock, so a None-check alone races
            lease = s.lease
            if lease is not None:
                for d in lease.devices:
                    held[d.kind] = held.get(d.kind, 0) + 1
        return held

    def _preempt_for(self, rec: JobRecord) -> int:
        """Ask lower-priority preemptible jobs to yield enough capacity to
        place ``rec``. Preemption rights come from **base** priorities
        only — aging reorders the queue but never grants the right to
        tear down a peer, so two equal-priority preemptible jobs can
        never ping-pong each other. Greedy victim choice: lowest base
        priority first, then least held (cheapest lost work), skipping
        victims whose devices cannot reduce any unmet requirement, until
        the deficit is covered; if even preempting every eligible victim
        cannot cover it, preempt nothing (tearing jobs down without
        unblocking anyone is pure waste). Capacity already yielding
        (victims asked earlier, PREEMPTING jobs mid-teardown) counts
        toward the deficit so repeated scheduler passes never
        over-preempt. Returns the number of *new* preemption requests
        issued."""
        with self._lock:
            if rec.status != JobStatus.QUEUED:
                return 0
            need: Dict[Optional[str], int] = {}
            for t in rec.spec.tasks:
                need[t.kind] = need.get(t.kind, 0) + t.n_devices
            rbase = rec.spec.effective_priority
            incoming: Dict[str, int] = {}
            candidates: List[JobRecord] = []
            for r in self._jobs.values():
                if r.status == JobStatus.PREEMPTING or (
                        r.status == JobStatus.RUNNING
                        and r.preempt_requested):
                    for k, n in self._held_by_kind(r).items():
                        incoming[k] = incoming.get(k, 0) + n
                elif (r.status == JobStatus.RUNNING and r.spec.preemptible
                      and r.spec.effective_priority < rbase):
                    candidates.append(r)

            free = {k: self.pool.free_count(k)
                    for k in need if k is not None}
            free_total = self.pool.free_count(None)
            total_need = sum(need.values())

            def named_unmet(extra: Dict[str, int]) -> List[str]:
                return [k for k, n in need.items()
                        if k is not None and (free[k] + incoming.get(k, 0)
                                              + extra.get(k, 0)) < n]

            def total_unmet(extra: Dict[str, int]) -> bool:
                supply = (free_total + sum(incoming.values())
                          + sum(extra.values()))
                return supply < total_need

            def covered(extra: Dict[str, int]) -> bool:
                # mirrors DevicePool.can_allocate_many: every named kind
                # from its own supply, the kind-agnostic remainder from
                # the total
                return not named_unmet(extra) and not total_unmet(extra)

            if covered({}):
                return 0  # enough capacity free or already on its way
            chosen: List[JobRecord] = []
            extra: Dict[str, int] = {}
            candidates.sort(key=lambda r: (
                r.spec.effective_priority,
                sum(self._held_by_kind(r).values())))
            # two passes: victims holding a still-short named kind first
            # (their devices count toward the total too), then — only if
            # the total is still short — any-kind victims. This never
            # sheds a job whose devices cannot reduce the deficit.
            for named_pass in (True, False):
                for r in candidates:
                    if r in chosen:
                        continue
                    held = self._held_by_kind(r)
                    if named_pass:
                        if not any(held.get(k, 0)
                                   for k in named_unmet(extra)):
                            continue
                    elif not (total_unmet(extra) and sum(held.values())):
                        continue
                    chosen.append(r)
                    for k, n in held.items():
                        extra[k] = extra.get(k, 0) + n
                    if covered(extra):
                        break
                if covered(extra):
                    break
            if not covered(extra):
                return 0  # cannot unblock even with every victim —
                          # don't shed work for nothing
            for r in chosen:
                self._request_preempt(r, relocate=False)
            return len(chosen)

    def _request_preempt(self, rec: JobRecord, relocate: bool):
        rec.preempt_requested = True
        rec.preempt_reason = "relocate" if relocate else "preempt"
        for s in rec.slices:
            s.request_preempt()
        self._log(rec, f"{rec.preempt_reason}_requested")

    def preempt_job(self, job_id: int) -> bool:
        """Operator API: ask a running preemptible job to yield."""
        with self._lock:
            rec = self._jobs[job_id]
            if (rec.status != JobStatus.RUNNING or not rec.spec.preemptible
                    or rec.preempt_requested):
                return False
            self._request_preempt(rec, relocate=False)
        return True

    # -- defragmentation (DESIGN.md §9) ------------------------------------
    def defragment(self, kind: Optional[str] = None,
                   max_moves: Optional[int] = None,
                   frag_threshold: Optional[float] = None) -> int:
        """Idle-time compaction: when the pool's fragmentation metric
        exceeds the threshold, ask up to ``max_moves`` relocatable jobs —
        ranked by how much contiguous capacity their lease's release
        re-opens — to checkpoint and requeue. Their best-fit re-placement
        packs them into the smallest holes that fit, re-coalescing large
        runs. Per-job ``relocation_limit`` bounds churn. Returns the
        number of relocation requests issued."""
        max_moves = (self.defrag_max_moves if max_moves is None
                     else max_moves)
        threshold = (self.frag_threshold if frag_threshold is None
                     else frag_threshold)
        with self._lock:
            if self.pool.fragmentation(kind) <= threshold:
                return 0
            owner: Dict[int, JobRecord] = {}
            for r in self._jobs.values():
                if (r.status == JobStatus.RUNNING and r.spec.relocatable
                        and not r.preempt_requested
                        and r.relocations < self.relocation_limit):
                    for s in r.slices:
                        lease = s.lease   # job thread may null it — snap
                        if lease is not None:
                            owner[lease.lease_id] = r
            moves = 0
            for lease_id in self.pool.compaction_candidates(kind):
                r = owner.get(lease_id)
                if r is None or r.preempt_requested:
                    continue
                self._request_preempt(r, relocate=True)
                moves += 1
                if moves >= max_moves:
                    break
        if moves:
            self._wakeup()
        return moves

    # -- job execution -----------------------------------------------------
    def _checkpoint_manager(self, directory: str):
        cls = type(self)._ckpt_cls
        if cls is None:
            from repro.checkpoint.manager import CheckpointManager
            cls = CheckpointManager
        return cls(directory)

    def _run_job(self, rec: JobRecord):
        current: Optional[Slice] = None
        preempted = False
        try:
            results = []
            for t, s in zip(rec.spec.tasks, rec.slices):
                current = s
                s.launch_machine(simulate_boot_s=self.simulate_boot_s)
                self._log(rec, f"{t.name}:launched")
                if t.checkpoint_dir is not None and s.ckpt is None:
                    s.ckpt = self._checkpoint_manager(t.checkpoint_dir)
                s.prepare_task(t.prepare_fn)
                self._log(rec, f"{t.name}:prepared")
                results.append(s.launch_task(t.task_fn))
                self._log(rec, f"{t.name}:finished")
                s.detach_device()
                s.destroy_machine()
            rec.result = results if len(results) > 1 else results[0]
            rec.status = JobStatus.DONE
        except Preempted as sig:
            preempted = True
            self._requeue_preempted(rec, sig, current)
        except BaseException as e:  # noqa: BLE001 — job isolation
            rec.error = f"{type(e).__name__}: {e}"
            rec.status = JobStatus.FAILED
            for s in rec.slices:
                if s.lease is not None:
                    try:
                        self.pool.release(s.lease)
                    except Exception:
                        pass  # index already saw it / pool poisoned —
                        # the terminal transition below must still land
                    s.lease = None
        finally:
            # the completion wakeup must fire no matter how the cleanup
            # above went — a FAILED job that never notifies wedges
            # wait()/run_until_idle for the full timeout
            if not preempted:
                # a victim that finished (or died) instead of yielding
                # must not read as still-yielding: quiescent() and the
                # preemption deficit accounting both consult this flag
                rec.preempt_requested = False
                rec.end_time = self._now()
                self._log(rec, rec.status.value)
                self._wakeup()

    def _requeue_preempted(self, rec: JobRecord, sig: Preempted,
                           active_slice: Optional[Slice]):
        """checkpoint → teardown → requeue. Any failure along the way
        (unsaveable state, missing checkpoint config, teardown error) must
        surface the job as FAILED with its leases released — leaving it
        PREEMPTING forever would wedge run_until_idle/wait on a condition
        variable that never signals completion."""
        with self._lock:
            relocate = rec.preempt_reason == "relocate"
            rec.status = JobStatus.PREEMPTING
            self._log(rec, "preempting")
        try:
            if sig.state is not None:
                if active_slice is None or active_slice.ckpt is None:
                    raise RuntimeError(
                        "task yielded checkpoint state but its TaskSpec "
                        "has no checkpoint_dir")
                active_slice.ckpt.save(sig.step, sig.state, blocking=True)
            for s in rec.slices:
                s.teardown()
            with self._lock:
                rec.slices = []
                rec.preempt_requested = False
                if relocate:
                    rec.relocations += 1
                else:
                    rec.preemptions += 1
                # requeue restarts the aging clock: boost accrues from
                # submit_time, which by now covers the victim's *running*
                # life — carrying it over would let the victim's aged
                # priority outrank the (lower-boost, higher-base) job it
                # just yielded to and reclaim the freed capacity in a
                # preempt/requeue livelock
                rec.submit_time = self._now()
                rec.status = JobStatus.QUEUED
                self._queue.append(rec)
                self._log(rec, "relocated" if relocate else "preempted")
        except BaseException as e:  # noqa: BLE001 — must end terminal
            for s in rec.slices:
                if s.lease is not None:
                    try:
                        self.pool.release(s.lease)
                    except Exception:
                        pass
                    s.lease = None
            with self._lock:
                rec.error = (f"mid-preemption failure: "
                             f"{type(e).__name__}: {e}")
                rec.status = JobStatus.FAILED
                rec.preempt_requested = False
                rec.end_time = self._now()
                self._log(rec, "failed")
        finally:
            self._wakeup()

    # -- drive to completion -----------------------------------------------
    def _busy(self) -> bool:
        return bool(self._queue) or any(
            r.status in (JobStatus.RUNNING, JobStatus.ALLOCATING,
                         JobStatus.PREEMPTING)
            for r in self._jobs.values())

    def run_until_idle(self, poll_s: Optional[float] = None,
                       timeout_s: float = 600.0):
        """Schedule until the queue drains and all jobs finish.

        Event-driven: blocks on the scheduler condition between passes —
        woken by submissions, completions, and pool releases. ``poll_s`` is
        kept for API compatibility; it no longer drives a sleep loop.
        """
        del poll_s  # legacy polling interval — wakeups are event-driven now
        deadline = time.perf_counter() + timeout_s
        while True:
            with self._wake_cond:
                seq = self._wake_seq
            dispatched = self.schedule_once()
            if self.auto_defrag and dispatched == 0:
                # idle pass: nothing placeable right now — spend the lull
                # re-coalescing free runs
                self.defragment()
            with self._lock:
                busy = self._busy()
            if not busy:
                return
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                raise TimeoutError("jobs did not finish before timeout")
            with self._wake_cond:
                # an event between the seq snapshot and here bumped the
                # counter — skip the wait and re-check instead of sleeping
                if self._wake_seq == seq:
                    self._wake_cond.wait(remaining)

    def wait(self, job_id: int, timeout_s: float = 600.0) -> JobRecord:
        deadline = time.perf_counter() + timeout_s
        while True:
            with self._wake_cond:
                seq = self._wake_seq
            self.schedule_once()
            with self._lock:
                rec = self._jobs[job_id]
                done = rec.status in _TERMINAL
                th = self._threads.get(job_id)
            if done:
                break
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                raise TimeoutError(f"job {job_id} did not finish")
            with self._wake_cond:
                if self._wake_seq == seq:
                    self._wake_cond.wait(remaining)
        # join with the *remaining* deadline budget — not the full timeout
        # again — so wait() blocks at most ~timeout_s in total
        if th is not None:
            th.join(timeout=max(0.0, deadline - time.perf_counter()))
        return rec

    # -- internals -----------------------------------------------------------
    def _now(self) -> float:
        return time.perf_counter() - self._t0

    def _log(self, rec: JobRecord, event: str):
        self.events.append((self._now(), rec.spec.name, event))
