"""Slice: a dynamically constructed execution environment (paper §3).

A slice is the unit FlowOS-RM hands to a job: a set of leased accelerators
shaped into a mesh, with the paper's six-operation lifecycle
(Fig. 2 / Table 1) as an explicit, *instrumented* state machine:

    attach-device   -> lease accelerators from the pool
    launch-machine  -> build the jax Mesh + boot runtime state
    prepare-task    -> lower + compile the task executable, stage data
    launch-task     -> run the task (training / serving loop)
    detach-device   -> return accelerators to the pool
    destroy-machine -> drop mesh and runtime state

Every transition is timed; ``breakdown()`` reproduces the Fig. 4 stacks.
"""
from __future__ import annotations

import dataclasses
import enum
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.pool import DevicePool, Lease


class SliceState(enum.Enum):
    CREATED = "created"
    ATTACHED = "attached"
    LAUNCHED = "launched"
    PREPARED = "prepared"
    RUNNING = "running"
    DONE = "done"
    DETACHED = "detached"
    DESTROYED = "destroyed"


class LifecycleError(RuntimeError):
    pass


# pre-states are tuples: detach_device accepts any settled post-attach
# state so a slice that never ran a task (meta-accelerator stage, aborted
# job) can still return its devices and end DESTROYED instead of rotting
# in ATTACHED. RUNNING is deliberately excluded — interrupting a live
# task is the elasticity layer's decision, not a teardown shortcut.
_VALID = {
    "attach_device": ((SliceState.CREATED,), SliceState.ATTACHED),
    "launch_machine": ((SliceState.ATTACHED,), SliceState.LAUNCHED),
    "prepare_task": ((SliceState.LAUNCHED,), SliceState.PREPARED),
    "launch_task": ((SliceState.PREPARED,), SliceState.RUNNING),
    "detach_device": ((SliceState.ATTACHED, SliceState.LAUNCHED,
                       SliceState.PREPARED, SliceState.DONE),
                      SliceState.DETACHED),
    "destroy_machine": ((SliceState.DETACHED,), SliceState.DESTROYED),
}


@dataclasses.dataclass
class Slice:
    name: str
    pool: DevicePool
    n_devices: int
    mesh_shape: Optional[Tuple[int, ...]] = None
    axis_names: Optional[Tuple[str, ...]] = None
    kind: Optional[str] = None
    prefer_contiguous: bool = True   # pod-local best-fit vs scatter

    state: SliceState = SliceState.CREATED
    lease: Optional[Lease] = None
    mesh: Any = None
    executable: Any = None
    timings: Dict[str, float] = dataclasses.field(default_factory=dict)
    events: List[Tuple[float, str]] = dataclasses.field(default_factory=list)
    # checkpoint handle (repro.checkpoint.manager.CheckpointManager) the RM
    # attaches when the owning TaskSpec carries a checkpoint_dir; task_fns
    # use it to restore on (re)start and the RM uses it to persist the
    # state a Preempted signal yields.
    ckpt: Any = None
    # named HBM reservations against this slice (bytes): long-lived
    # device-resident pools a task pins for its whole run — the serving
    # engine registers its KV page pool here (DESIGN.md §10), so slice
    # accounting sees the memory a job holds, not just the devices
    hbm: Dict[str, int] = dataclasses.field(default_factory=dict)
    # (mesh, NamedSharding) cache for replicated_sharding()
    _repl_sharding: Any = dataclasses.field(default=None, repr=False)
    # cooperative-preemption flag: the RM sets it, the running task polls
    # it at safe points (a threading.Event so the handoff is race-free)
    _preempt: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False)

    # ------------------------------------------------------------------
    def _transition(self, op: str, fn: Callable[[], Any]):
        pre, post = _VALID[op]
        if self.state not in pre:
            want = " or ".join(s.value for s in pre)
            raise LifecycleError(
                f"{self.name}: {op} requires state {want}, "
                f"slice is {self.state.value}")
        t0 = time.perf_counter()
        self.events.append((t0, f"{op}:start"))
        result = fn()
        dt = time.perf_counter() - t0
        self.timings[op] = self.timings.get(op, 0.0) + dt
        self.events.append((time.perf_counter(), f"{op}:end"))
        self.state = post
        return result

    # -- lifecycle ------------------------------------------------------
    def attach_device(self):
        """Lease accelerators (paper: PCIe-over-Ethernet attach)."""
        def fn():
            self.lease = self.pool.acquire(
                self.n_devices, kind=self.kind,
                prefer_contiguous=self.prefer_contiguous)
        return self._transition("attach_device", fn)

    def launch_machine(self, simulate_boot_s: float = 0.0):
        """Build the mesh over leased devices (paper: boot node w/ BMC)."""
        def fn():
            if simulate_boot_s:
                time.sleep(simulate_boot_s)
            devs = self.lease.jax_devices()
            if self.mesh_shape is not None and all(
                    d is not None for d in devs):
                import jax
                arr = np.array(devs).reshape(self.mesh_shape)
                self.mesh = jax.sharding.Mesh(arr, self.axis_names)
            return self.mesh
        return self._transition("launch_machine", fn)

    def prepare_task(self, prepare_fn: Optional[Callable] = None):
        """Compile executables / stage data (paper: submit via Mesos)."""
        def fn():
            if prepare_fn is not None:
                self.executable = prepare_fn(self)
            return self.executable
        return self._transition("prepare_task", fn)

    def launch_task(self, task_fn: Optional[Callable] = None):
        """Run the task to completion. Returns the task result."""
        def fn():
            if task_fn is not None:
                return task_fn(self)
            return None
        result = self._transition("launch_task", fn)
        # run-task time is the dominant Fig. 4 component
        self.timings["run_task"] = self.timings.pop("launch_task")
        self.state = SliceState.DONE
        return result

    def detach_device(self):
        def fn():
            if self.lease is not None:
                self.pool.release(self.lease)
                self.lease = None
        return self._transition("detach_device", fn)

    def destroy_machine(self):
        def fn():
            self.mesh = None
            self.executable = None
            self._repl_sharding = None
            self.hbm.clear()
        return self._transition("destroy_machine", fn)

    def teardown(self):
        """Run whatever lifecycle teardown remains from the current
        state: detach_device (if a lease-bearing state) then
        destroy_machine. No-op for CREATED/DESTROYED slices, so it is
        safe on partially-constructed stage sets (meta-accelerator
        rollback) and idempotent. Raises for a RUNNING slice — stopping
        a live task is the elasticity layer's decision, and silently
        skipping it would leak the lease."""
        if self.state == SliceState.RUNNING:
            raise LifecycleError(
                f"{self.name}: cannot teardown a running slice")
        if self.state in _VALID["detach_device"][0]:
            self.detach_device()
        if self.state == SliceState.DETACHED:
            self.destroy_machine()

    def request_preempt(self):
        """Ask the task running on this slice to yield at its next safe
        point (cooperative — nothing is interrupted)."""
        self._preempt.set()

    def preempt_requested(self) -> bool:
        """Polled by cooperating task_fns; when True the task should
        raise ``repro.core.Preempted`` (optionally with its state)."""
        return self._preempt.is_set()

    def wait_preempt(self, timeout_s: Optional[float] = None) -> bool:
        """Block until a preemption request lands (or ``timeout_s``
        passes); returns preempt_requested(). Lets an idle-phase task
        sleep in C instead of poll-spinning — hundreds of cooperative
        jobs waiting this way cost no scheduler churn, and the wake is
        immediate when the RM asks."""
        return self._preempt.wait(timeout_s)

    # -- HBM accounting -------------------------------------------------
    def account_hbm(self, name: str, nbytes: int):
        """Register (or update) a named device-memory reservation, e.g.
        ``slice.account_hbm("kv_pages", cache.hbm_bytes)``."""
        self.hbm[name] = int(nbytes)

    def release_hbm(self, name: str):
        self.hbm.pop(name, None)

    def hbm_bytes(self) -> int:
        """Total bytes of named reservations currently accounted."""
        return sum(self.hbm.values())

    def replicated_sharding(self):
        """Cached fully-replicated NamedSharding over this slice's mesh.
        The data plane issues one device_put per microbatch per hop;
        rebuilding the sharding object each time is measurable overhead,
        so it is cached until the mesh changes (None while no mesh)."""
        if self.mesh is None:
            return None
        cached = self._repl_sharding
        if cached is None or cached[0] is not self.mesh:
            import jax
            self._repl_sharding = (self.mesh, jax.sharding.NamedSharding(
                self.mesh, jax.sharding.PartitionSpec()))
        return self._repl_sharding[1]

    # ------------------------------------------------------------------
    def run_lifecycle(self, prepare_fn=None, task_fn=None,
                      simulate_boot_s: float = 0.0):
        """Full six-operation lifecycle; returns (result, breakdown)."""
        self.attach_device()
        self.launch_machine(simulate_boot_s=simulate_boot_s)
        self.prepare_task(prepare_fn)
        result = self.launch_task(task_fn)
        self.detach_device()
        self.destroy_machine()
        return result, self.breakdown()

    def breakdown(self) -> Dict[str, float]:
        """Per-operation wall time (the Fig. 4 stack for this slice)."""
        order = ["attach_device", "launch_machine", "prepare_task",
                 "run_task", "detach_device", "destroy_machine"]
        return {k: self.timings.get(k, 0.0) for k in order}

    def overhead_fraction(self) -> float:
        """construction+destruction / total (paper: 32-45% MNIST,
        0.15-0.17% ImageNet)."""
        b = self.breakdown()
        total = sum(b.values())
        run = b.get("run_task", 0.0)
        return (total - run) / total if total > 0 else 0.0
