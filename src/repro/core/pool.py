"""Disaggregated accelerator pool (the FiC resource pool).

The pool tracks every accelerator in the fleet — which node block it lives
on, its kind (the paper's meta-accelerator heterogeneity: GPU + FPGA pools;
here: device kinds), health, and current lease. ``acquire`` implements the
placement policy: prefer topology-contiguous blocks (the TPU analogue of the
paper's "attach the closest remote device through the FiC network" — slices
spanning pods pay slower links, see DESIGN.md §2).

Placement is served from an incrementally-maintained **free-run index**
(DESIGN.md §3): sorted runs of contiguous free uids, bucketed per
(pod, kind), updated in O(log n) on ``acquire`` / ``release`` /
``mark_failed`` / ``mark_repaired``. Best-fit run selection (smallest run
that satisfies the request) keeps fragmentation low; the old implementation
re-sorted and rescanned the entire free list on every ``acquire``, which
does not survive 100k-device fleets.

Devices may be real ``jax.Device`` objects (dry-run / training) or virtual
descriptors (scheduler-level tests and 100k-node simulations).
"""
from __future__ import annotations

import bisect
import dataclasses
import itertools
import threading
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple)


@dataclasses.dataclass
class DeviceInfo:
    uid: int
    node: int              # host / node-block index
    pod: int               # ICI domain (pod) index
    kind: str = "tpu"      # accelerator kind (meta-accelerator support)
    healthy: bool = True
    device: Any = None     # underlying jax.Device, if real
    lease_id: Optional[int] = None


@dataclasses.dataclass
class Lease:
    lease_id: int
    devices: List[DeviceInfo]
    kind: str

    @property
    def n(self) -> int:
        return len(self.devices)

    @property
    def pods(self) -> set:
        return {d.pod for d in self.devices}

    @property
    def nodes(self) -> set:
        return {d.node for d in self.devices}

    @property
    def cross_pod(self) -> bool:
        return len(self.pods) > 1

    def jax_devices(self) -> list:
        return [d.device for d in self.devices]


class AllocationError(RuntimeError):
    pass


Bucket = Tuple[int, str]   # (pod, kind)
Run = Tuple[int, int]      # half-open uid range [start, end)


class FreeRunIndex:
    """Sorted contiguous free-uid runs, bucketed per (pod, kind).

    Each bucket keeps two parallel sorted lists: runs ordered by start uid
    (for merge/split when uids enter or leave the free set) and by
    (length, start) (for best-fit lookup). All mutations are a bisect plus
    a couple of list inserts/deletes — O(log n) search with C-speed
    memmoves — against the seed's full sort + rescan per acquire.
    Per-kind free counts make feasibility checks O(1).

    The index is deliberately unit-agnostic: a "uid" is any densely
    numbered resource. DevicePool buckets accelerators per (pod, kind);
    the serving plane's PagedKVCache (serve/kv_cache.py) buckets KV-cache
    pages in one HBM pool — one allocator abstraction places both devices
    in the fabric and pages in HBM (DESIGN.md §10).
    """

    def __init__(self):
        self._by_start: Dict[Bucket, List[Run]] = {}
        self._by_len: Dict[Bucket, List[Run]] = {}   # (length, start)
        self._kind_free: Dict[str, int] = {}
        self._total_free = 0

    # -- low-level run surgery -------------------------------------------
    def _insert_run(self, bucket: Bucket, start: int, end: int):
        bisect.insort(self._by_start[bucket], (start, end))
        bisect.insort(self._by_len[bucket], (end - start, start))

    def _delete_run(self, bucket: Bucket, start: int, end: int):
        runs = self._by_start[bucket]
        del runs[bisect.bisect_left(runs, (start, end))]
        lens = self._by_len[bucket]
        del lens[bisect.bisect_left(lens, (end - start, start))]

    # -- mutation ---------------------------------------------------------
    def add_range(self, bucket: Bucket, start: int, end: int):
        """[start, end) became free: insert, merging with adjacent runs."""
        runs = self._by_start.setdefault(bucket, [])
        self._by_len.setdefault(bucket, [])
        i = bisect.bisect_left(runs, (start, start))
        merged_start, merged_end = start, end
        if i < len(runs) and runs[i][0] == end:          # merge right
            merged_end = runs[i][1]
            self._delete_run(bucket, runs[i][0], runs[i][1])
        if i > 0 and runs[i - 1][1] == start:            # merge left
            prev = runs[i - 1]
            merged_start = prev[0]
            self._delete_run(bucket, prev[0], prev[1])
        self._insert_run(bucket, merged_start, merged_end)
        self._kind_free[bucket[1]] = (self._kind_free.get(bucket[1], 0)
                                      + end - start)
        self._total_free += end - start

    def remove_range(self, bucket: Bucket, start: int, end: int):
        """[start, end) became non-free; must lie within a single run."""
        runs = self._by_start[bucket]
        i = bisect.bisect_right(runs, (start, float("inf"))) - 1
        rs, re = runs[i]
        if not (rs <= start and end <= re):
            raise AssertionError(
                f"free-run index corrupt: [{start},{end}) not in run "
                f"[{rs},{re}) of bucket {bucket}")
        self._delete_run(bucket, rs, re)
        if rs < start:
            self._insert_run(bucket, rs, start)
        if end < re:
            self._insert_run(bucket, end, re)
        self._kind_free[bucket[1]] -= end - start
        self._total_free -= end - start

    # -- queries ----------------------------------------------------------
    def free_count(self, kind: Optional[str] = None) -> int:
        if kind is None:
            return self._total_free
        return self._kind_free.get(kind, 0)

    def _buckets_for(self, kind: Optional[str]) -> List[Bucket]:
        return [b for b in self._by_start
                if kind is None or b[1] == kind]

    def best_fit(self, n: int, kind: Optional[str]) -> Optional[Run]:
        """Smallest single-bucket run with length >= n (ties: lowest uid).
        A single-bucket run never spans pods."""
        best = None
        for b in self._buckets_for(kind):
            lens = self._by_len[b]
            j = bisect.bisect_left(lens, (n, -1))
            if j < len(lens) and (best is None or lens[j] < best):
                best = lens[j]
        if best is None:
            return None
        length, start = best
        return (start, start + length)

    def runs_ascending(self, kind: Optional[str]) -> List[Run]:
        """All runs for matching kinds, ascending by start uid."""
        out: List[Run] = []
        for b in self._buckets_for(kind):
            out.extend(self._by_start[b])
        out.sort()
        return out

    def best_fit_coalesced(self, n: int, kind: Optional[str]
                           ) -> Optional[Run]:
        """Best-fit over runs coalesced across bucket boundaries (a
        contiguous uid span may cross pods — the DCN-spanning fallback)."""
        best = None
        start = end = None
        for rs, re in self.runs_ascending(kind) + [(None, None)]:
            if start is not None and rs == end:
                end = re
                continue
            if start is not None and end - start >= n:
                cand = (end - start, start)
                if best is None or cand < best:
                    best = cand
            start, end = rs, re
        if best is None:
            return None
        length, s = best
        return (s, s + length)

    def largest_run(self, kind: Optional[str] = None) -> int:
        """Length of the largest single-bucket (pod-local) free run."""
        best = 0
        for b in self._buckets_for(kind):
            lens = self._by_len[b]
            if lens and lens[-1][0] > best:
                best = lens[-1][0]
        return best

    def merged_run_size(self, bucket: Bucket, start: int, end: int) -> int:
        """Size of the free run that would exist in ``bucket`` if
        [start, end) were freed: the span plus whatever free runs it is
        adjacent to. The defragmentation pass ranks relocation candidates
        by this — the lease whose release re-opens the largest run moves
        first."""
        runs = self._by_start.get(bucket, [])
        j = bisect.bisect_left(runs, (start, -1))
        size = end - start
        if j > 0 and runs[j - 1][1] == start:
            size += runs[j - 1][1] - runs[j - 1][0]
        if j < len(runs) and runs[j][0] == end:
            size += runs[j][1] - runs[j][0]
        return size

    def snapshot(self) -> Dict[Bucket, List[Run]]:
        """Copy of all buckets' runs (tests / introspection)."""
        return {b: list(runs) for b, runs in self._by_start.items() if runs}


def _bucket_spans(devs: Sequence[DeviceInfo]):
    """Group an ascending-uid device list into maximal contiguous
    same-(pod, kind) spans — one index mutation per span, not per uid."""
    spans: List[List] = []  # [bucket, start, end]
    for d in devs:
        bucket = (d.pod, d.kind)
        if spans and spans[-1][0] == bucket and spans[-1][2] == d.uid:
            spans[-1][2] = d.uid + 1
        else:
            spans.append([bucket, d.uid, d.uid + 1])
    return spans


class DevicePool:
    """Lease accounting + contiguity-aware placement over the fleet."""

    def __init__(self, devices: Sequence[DeviceInfo]):
        self._devices = list(devices)
        self._by_uid = {d.uid: d for d in self._devices}
        self._lock = threading.RLock()
        self._lease_counter = itertools.count()
        self._leases: Dict[int, Lease] = {}
        self._index = FreeRunIndex()
        self._release_listeners: List[Callable[[], None]] = []
        free = sorted((d for d in self._devices
                       if d.healthy and d.lease_id is None),
                      key=lambda d: d.uid)
        for bucket, start, end in _bucket_spans(free):
            self._index.add_range(bucket, start, end)

    # -- constructors ----------------------------------------------------
    @classmethod
    def from_jax_devices(cls, devices=None, devices_per_node: int = 8,
                         devices_per_pod: int = 256, kind: str = "tpu"):
        import jax
        devices = list(devices if devices is not None else jax.devices())
        infos = [DeviceInfo(uid=i, node=i // devices_per_node,
                            pod=i // devices_per_pod, kind=kind, device=d)
                 for i, d in enumerate(devices)]
        return cls(infos)

    @classmethod
    def virtual(cls, n_devices: int, devices_per_node: int = 8,
                devices_per_pod: int = 256, kinds: Optional[dict] = None):
        """Virtual fleet; ``kinds`` maps uid-range tuples to kind names."""
        infos = []
        for i in range(n_devices):
            kind = "tpu"
            for (lo, hi), k in (kinds or {}).items():
                if lo <= i < hi:
                    kind = k
            infos.append(DeviceInfo(uid=i, node=i // devices_per_node,
                                    pod=i // devices_per_pod, kind=kind))
        return cls(infos)

    # -- event hooks ------------------------------------------------------
    def add_release_listener(self, fn: Callable[[], None]):
        """``fn()`` runs (outside the pool lock) whenever capacity returns
        to the pool — lease release or device repair. FlowOS-RM hooks its
        scheduler wakeup here (DESIGN.md §4)."""
        with self._lock:
            self._release_listeners.append(fn)

    def remove_release_listener(self, fn: Callable[[], None]):
        with self._lock:
            if fn in self._release_listeners:
                self._release_listeners.remove(fn)

    def _notify_release(self):
        for fn in list(self._release_listeners):
            fn()

    # -- queries ----------------------------------------------------------
    @property
    def size(self) -> int:
        return len(self._devices)

    def free_devices(self, kind: Optional[str] = None) -> List[DeviceInfo]:
        with self._lock:
            return [d for d in self._devices
                    if d.healthy and d.lease_id is None
                    and (kind is None or d.kind == kind)]

    def free_count(self, kind: Optional[str] = None) -> int:
        """O(1) free-device count from the index (no fleet scan)."""
        with self._lock:
            return self._index.free_count(kind)

    def free_runs(self) -> Dict[Bucket, List[Run]]:
        """Free-run index snapshot: {(pod, kind): [(start, end), ...]}."""
        with self._lock:
            return self._index.snapshot()

    def largest_free_run(self, kind: Optional[str] = None) -> int:
        """Largest pod-local contiguous free run (placement quality)."""
        with self._lock:
            return self._index.largest_run(kind)

    def fragmentation(self, kind: Optional[str] = None) -> float:
        """Fragmentation metric (DESIGN.md §9): ``1 - largest_free_run /
        total_free``. 0.0 when every free device sits in one pod-local
        contiguous run (or nothing is free); approaches 1.0 as the free
        capacity shatters into many small runs. This is what drives the
        idle-time compaction pass in FlowOS-RM."""
        with self._lock:
            free = self._index.free_count(kind)
            if free <= 0:
                return 0.0
            return 1.0 - self._index.largest_run(kind) / free

    def compaction_candidates(self, kind: Optional[str] = None,
                              limit: Optional[int] = None) -> List[int]:
        """Lease ids ranked by how much contiguous capacity their release
        would re-open (merged-run size desc, then smaller leases first —
        cheapest moves). Only single-span leases adjacent to at least one
        free run qualify: a lease with no free neighbours re-opens
        nothing, and a scattered lease is not a meaningful unit of
        relocation. FlowOS-RM's defragment() maps these back to
        relocatable jobs."""
        with self._lock:
            scored = []
            for lease in self._leases.values():
                devs = sorted(lease.devices, key=lambda d: d.uid)
                spans = _bucket_spans(devs)
                if len(spans) != 1:
                    continue
                bucket, start, end = spans[0]
                if kind is not None and bucket[1] != kind:
                    continue
                merged = self._index.merged_run_size(bucket, start, end)
                if merged == end - start:
                    continue  # no adjacent free run — moving it gains 0
                scored.append((-merged, end - start, lease.lease_id))
            scored.sort()
            ids = [lease_id for _, _, lease_id in scored]
            return ids[:limit] if limit is not None else ids

    def utilization(self) -> float:
        with self._lock:
            healthy = sum(1 for d in self._devices if d.healthy)
            leased = sum(1 for d in self._devices
                         if d.healthy and d.lease_id is not None)
            return leased / max(healthy, 1)

    def leases(self) -> List[Lease]:
        with self._lock:
            return list(self._leases.values())

    # -- allocation --------------------------------------------------------
    def can_allocate(self, n: int, kind: Optional[str] = None) -> bool:
        return self.free_count(kind) >= n

    def can_allocate_many(self, need: Dict[Optional[str], int]) -> bool:
        """Feasibility for a co-allocation request ({kind: n}) in one lock
        round-trip — what FlowOS-RM asks before dispatching a job.

        Exact for mixed requests: each named kind must be covered by its
        own free devices, and the kind-agnostic (None) demand by whatever
        remains, i.e. total free >= total demand. (The seed checked each
        kind independently, double-counting devices when a job mixed
        kind=None with a named kind.)"""
        with self._lock:
            total = 0
            for k, n in need.items():
                total += n
                if k is not None and self._index.free_count(k) < n:
                    return False
            return self._index.free_count(None) >= total

    def acquire(self, n: int, kind: Optional[str] = None,
                prefer_contiguous: bool = True) -> Lease:
        """attach-device: lease n devices, preferring a contiguous block
        within one pod (lowest-latency ICI placement)."""
        with self._lock:
            free_n = self._index.free_count(kind)
            if free_n < n:
                raise AllocationError(
                    f"need {n} {kind or 'any'} devices, {free_n} free")
            uids: Optional[List[int]] = None
            if prefer_contiguous and n > 0:
                run = self._index.best_fit(n, kind)
                if run is None:
                    run = self._index.best_fit_coalesced(n, kind)
                if run is not None:
                    uids = list(range(run[0], run[0] + n))
            if uids is None:
                uids = self._first_free_uids(n, kind)
            chosen = [self._by_uid[u] for u in uids]
            lease = Lease(next(self._lease_counter), chosen,
                          kind or "any")
            for d in chosen:
                d.lease_id = lease.lease_id
            for bucket, start, end in _bucket_spans(chosen):
                self._index.remove_range(bucket, start, end)
            self._leases[lease.lease_id] = lease
            return lease

    def _first_free_uids(self, n: int, kind: Optional[str]) -> List[int]:
        """Fragmented fallback: lowest n free uids (may span pods/runs)."""
        uids: List[int] = []
        for rs, re in self._index.runs_ascending(kind):
            take = min(n - len(uids), re - rs)
            uids.extend(range(rs, rs + take))
            if len(uids) == n:
                break
        return uids

    def release(self, lease: Lease):
        """detach-device: return devices to the pool."""
        with self._lock:
            back = []
            for d in lease.devices:
                if d.lease_id == lease.lease_id:
                    d.lease_id = None
                    if d.healthy:
                        back.append(d)
            back.sort(key=lambda d: d.uid)
            for bucket, start, end in _bucket_spans(back):
                self._index.add_range(bucket, start, end)
            self._leases.pop(lease.lease_id, None)
        self._notify_release()

    # -- failures ----------------------------------------------------------
    def mark_failed(self, uids: Sequence[int]):
        with self._lock:
            for uid in uids:
                d = self._by_uid[uid]
                if d.healthy:
                    d.healthy = False
                    if d.lease_id is None:
                        self._index.remove_range((d.pod, d.kind),
                                                 uid, uid + 1)

    def mark_repaired(self, uids: Sequence[int]):
        repaired = False
        with self._lock:
            for uid in uids:
                d = self._by_uid[uid]
                if not d.healthy:
                    d.healthy = True
                    if d.lease_id is None:
                        self._index.add_range((d.pod, d.kind),
                                              d.uid, d.uid + 1)
                        repaired = True
        if repaired:
            self._notify_release()

    def failed_in_lease(self, lease: Lease) -> List[DeviceInfo]:
        with self._lock:
            return [d for d in lease.devices if not d.healthy]
