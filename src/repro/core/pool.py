"""Disaggregated accelerator pool (the FiC resource pool).

The pool tracks every accelerator in the fleet — which node block it lives
on, its kind (the paper's meta-accelerator heterogeneity: GPU + FPGA pools;
here: device kinds), health, and current lease. ``acquire`` implements the
placement policy: prefer topology-contiguous blocks (the TPU analogue of the
paper's "attach the closest remote device through the FiC network" — slices
spanning pods pay slower links, see DESIGN.md §2).

Devices may be real ``jax.Device`` objects (dry-run / training) or virtual
descriptors (scheduler-level tests and 1000+-node simulations).
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import Any, Dict, List, Optional, Sequence


@dataclasses.dataclass
class DeviceInfo:
    uid: int
    node: int              # host / node-block index
    pod: int               # ICI domain (pod) index
    kind: str = "tpu"      # accelerator kind (meta-accelerator support)
    healthy: bool = True
    device: Any = None     # underlying jax.Device, if real
    lease_id: Optional[int] = None


@dataclasses.dataclass
class Lease:
    lease_id: int
    devices: List[DeviceInfo]
    kind: str

    @property
    def n(self) -> int:
        return len(self.devices)

    @property
    def pods(self) -> set:
        return {d.pod for d in self.devices}

    @property
    def nodes(self) -> set:
        return {d.node for d in self.devices}

    @property
    def cross_pod(self) -> bool:
        return len(self.pods) > 1

    def jax_devices(self) -> list:
        return [d.device for d in self.devices]


class AllocationError(RuntimeError):
    pass


class DevicePool:
    """Lease accounting + contiguity-aware placement over the fleet."""

    def __init__(self, devices: Sequence[DeviceInfo]):
        self._devices = list(devices)
        self._by_uid = {d.uid: d for d in self._devices}
        self._lock = threading.RLock()
        self._lease_counter = itertools.count()
        self._leases: Dict[int, Lease] = {}

    # -- constructors ----------------------------------------------------
    @classmethod
    def from_jax_devices(cls, devices=None, devices_per_node: int = 8,
                         devices_per_pod: int = 256, kind: str = "tpu"):
        import jax
        devices = list(devices if devices is not None else jax.devices())
        infos = [DeviceInfo(uid=i, node=i // devices_per_node,
                            pod=i // devices_per_pod, kind=kind, device=d)
                 for i, d in enumerate(devices)]
        return cls(infos)

    @classmethod
    def virtual(cls, n_devices: int, devices_per_node: int = 8,
                devices_per_pod: int = 256, kinds: Optional[dict] = None):
        """Virtual fleet; ``kinds`` maps uid-range tuples to kind names."""
        infos = []
        for i in range(n_devices):
            kind = "tpu"
            for (lo, hi), k in (kinds or {}).items():
                if lo <= i < hi:
                    kind = k
            infos.append(DeviceInfo(uid=i, node=i // devices_per_node,
                                    pod=i // devices_per_pod, kind=kind))
        return cls(infos)

    # -- queries ----------------------------------------------------------
    @property
    def size(self) -> int:
        return len(self._devices)

    def free_devices(self, kind: Optional[str] = None) -> List[DeviceInfo]:
        with self._lock:
            return [d for d in self._devices
                    if d.healthy and d.lease_id is None
                    and (kind is None or d.kind == kind)]

    def utilization(self) -> float:
        with self._lock:
            healthy = [d for d in self._devices if d.healthy]
            leased = [d for d in healthy if d.lease_id is not None]
            return len(leased) / max(len(healthy), 1)

    def leases(self) -> List[Lease]:
        with self._lock:
            return list(self._leases.values())

    # -- allocation --------------------------------------------------------
    def can_allocate(self, n: int, kind: Optional[str] = None) -> bool:
        return len(self.free_devices(kind)) >= n

    def acquire(self, n: int, kind: Optional[str] = None,
                prefer_contiguous: bool = True) -> Lease:
        """attach-device: lease n devices, preferring a contiguous block
        within one pod (lowest-latency ICI placement)."""
        with self._lock:
            free = self.free_devices(kind)
            if len(free) < n:
                raise AllocationError(
                    f"need {n} {kind or 'any'} devices, {len(free)} free")
            chosen: Optional[List[DeviceInfo]] = None
            if prefer_contiguous:
                chosen = self._contiguous_block(free, n)
            if chosen is None:
                chosen = free[:n]  # fragmented fallback (may span pods)
            lease = Lease(next(self._lease_counter), chosen,
                          kind or "any")
            for d in chosen:
                d.lease_id = lease.lease_id
            self._leases[lease.lease_id] = lease
            return lease

    def _contiguous_block(self, free: List[DeviceInfo],
                          n: int) -> Optional[List[DeviceInfo]]:
        """First contiguous uid-run of length n, preferring single-pod."""
        free_sorted = sorted(free, key=lambda d: d.uid)
        for single_pod in (True, False):
            run: List[DeviceInfo] = []
            for d in free_sorted:
                if run and (d.uid != run[-1].uid + 1
                            or (single_pod and d.pod != run[-1].pod)):
                    run = []
                run.append(d)
                if len(run) == n:
                    return run
        return None

    def release(self, lease: Lease):
        """detach-device: return devices to the pool."""
        with self._lock:
            for d in lease.devices:
                if d.lease_id == lease.lease_id:
                    d.lease_id = None
            self._leases.pop(lease.lease_id, None)

    # -- failures ----------------------------------------------------------
    def mark_failed(self, uids: Sequence[int]):
        with self._lock:
            for uid in uids:
                self._by_uid[uid].healthy = False

    def mark_repaired(self, uids: Sequence[int]):
        with self._lock:
            for uid in uids:
                self._by_uid[uid].healthy = True

    def failed_in_lease(self, lease: Lease) -> List[DeviceInfo]:
        with self._lock:
            return [d for d in lease.devices if not d.healthy]
