"""Job / task descriptors + the REST-like submission surface.

A job is a set of tasks (paper §4: single-node and MPI-type multi-node jobs
are both supported — here: single-slice jobs and meta-accelerator jobs whose
tasks land on distinct sub-slices). Specs are plain serializable dataclasses
so the dict round-trip mirrors the paper's REST API.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Dict, List, Optional, Tuple


class JobStatus(enum.Enum):
    QUEUED = "queued"
    ALLOCATING = "allocating"
    RUNNING = "running"
    PREEMPTING = "preempting"   # checkpoint + teardown in flight
    DONE = "done"
    FAILED = "failed"
    CANCELLED = "cancelled"


class Preempted(Exception):
    """Raised by a cooperating ``task_fn`` when it observes
    ``slice.preempt_requested()``: the task has reached a safe point and
    yields its devices. ``state`` (optional) is a pytree the RM persists
    through the slice's ``CheckpointManager`` before teardown, so the
    requeued job can resume from ``step`` instead of from scratch."""

    def __init__(self, state: Any = None, step: int = 0):
        super().__init__(f"preempted at step {step}")
        self.state = state
        self.step = step


@dataclasses.dataclass
class TaskSpec:
    """One task of a job, bound to one (sub-)slice."""
    name: str
    n_devices: int
    mesh_shape: Optional[Tuple[int, ...]] = None
    axis_names: Optional[Tuple[str, ...]] = None
    kind: Optional[str] = None          # accelerator kind (meta-accel)
    prefer_contiguous: bool = True      # single-pod best-fit placement
    priority: int = 0                   # raises the job's effective priority
    checkpoint_dir: Optional[str] = None  # preemption save/restore root
    arch: Optional[str] = None          # model architecture id
    shape: Optional[str] = None         # input-shape cell name
    steps: int = 0                      # training steps (0 = driver-defined)
    # non-serializable hooks (driver-provided):
    prepare_fn: Optional[Callable] = None
    task_fn: Optional[Callable] = None

    def to_dict(self) -> Dict[str, Any]:
        return {k: v for k, v in dataclasses.asdict(self).items()
                if k not in ("prepare_fn", "task_fn")}


@dataclasses.dataclass
class JobSpec:
    name: str
    tasks: List[TaskSpec]
    priority: int = 0
    # Cooperative-preemption contract: the job's task_fns poll
    # slice.preempt_requested() and raise Preempted at safe points. The
    # RM only ever *asks*; a job that never opts in is never torn down.
    preemptible: bool = False
    # Relocatable jobs additionally accept being moved by the idle-time
    # defragmentation pass (same checkpoint/requeue protocol).
    relocatable: bool = False

    @property
    def n_devices(self) -> int:
        return sum(t.n_devices for t in self.tasks)

    @property
    def effective_priority(self) -> int:
        """Job priority: the max of the job-level priority and every
        task-level priority (a job is as urgent as its hottest task)."""
        return max([self.priority] + [t.priority for t in self.tasks])

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "priority": self.priority,
                "preemptible": self.preemptible,
                "relocatable": self.relocatable,
                "tasks": [t.to_dict() for t in self.tasks]}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "JobSpec":
        tasks = [TaskSpec(**t) for t in d["tasks"]]
        return cls(name=d["name"], tasks=tasks,
                   priority=d.get("priority", 0),
                   preemptible=d.get("preemptible", False),
                   relocatable=d.get("relocatable", False))


@dataclasses.dataclass
class JobRecord:
    job_id: int
    spec: JobSpec
    status: JobStatus = JobStatus.QUEUED
    slices: List[Any] = dataclasses.field(default_factory=list)
    result: Any = None
    error: Optional[str] = None
    submit_time: float = 0.0
    start_time: Optional[float] = None
    end_time: Optional[float] = None
    preemptions: int = 0        # completed preempt→requeue round-trips
    relocations: int = 0        # completed defrag moves
    preempt_requested: bool = False
    preempt_reason: str = "preempt"   # or "relocate" (defrag move)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "job_id": self.job_id,
            "name": self.spec.name,
            "status": self.status.value,
            "priority": self.spec.effective_priority,
            "submit_time": self.submit_time,
            "start_time": self.start_time,
            "end_time": self.end_time,
            "preemptions": self.preemptions,
            "relocations": self.relocations,
            "error": self.error,
            "breakdowns": [s.breakdown() for s in self.slices],
        }
