"""Job / task descriptors + the REST-like submission surface.

A job is a set of tasks (paper §4: single-node and MPI-type multi-node jobs
are both supported — here: single-slice jobs and meta-accelerator jobs whose
tasks land on distinct sub-slices). Specs are plain serializable dataclasses
so the dict round-trip mirrors the paper's REST API.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Dict, List, Optional, Tuple


class JobStatus(enum.Enum):
    QUEUED = "queued"
    ALLOCATING = "allocating"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"
    CANCELLED = "cancelled"


@dataclasses.dataclass
class TaskSpec:
    """One task of a job, bound to one (sub-)slice."""
    name: str
    n_devices: int
    mesh_shape: Optional[Tuple[int, ...]] = None
    axis_names: Optional[Tuple[str, ...]] = None
    kind: Optional[str] = None          # accelerator kind (meta-accel)
    prefer_contiguous: bool = True      # single-pod best-fit placement
    arch: Optional[str] = None          # model architecture id
    shape: Optional[str] = None         # input-shape cell name
    steps: int = 0                      # training steps (0 = driver-defined)
    # non-serializable hooks (driver-provided):
    prepare_fn: Optional[Callable] = None
    task_fn: Optional[Callable] = None

    def to_dict(self) -> Dict[str, Any]:
        return {k: v for k, v in dataclasses.asdict(self).items()
                if k not in ("prepare_fn", "task_fn")}


@dataclasses.dataclass
class JobSpec:
    name: str
    tasks: List[TaskSpec]
    priority: int = 0

    @property
    def n_devices(self) -> int:
        return sum(t.n_devices for t in self.tasks)

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "priority": self.priority,
                "tasks": [t.to_dict() for t in self.tasks]}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "JobSpec":
        tasks = [TaskSpec(**t) for t in d["tasks"]]
        return cls(name=d["name"], tasks=tasks,
                   priority=d.get("priority", 0))


@dataclasses.dataclass
class JobRecord:
    job_id: int
    spec: JobSpec
    status: JobStatus = JobStatus.QUEUED
    slices: List[Any] = dataclasses.field(default_factory=list)
    result: Any = None
    error: Optional[str] = None
    submit_time: float = 0.0
    start_time: Optional[float] = None
    end_time: Optional[float] = None

    def to_dict(self) -> Dict[str, Any]:
        return {
            "job_id": self.job_id,
            "name": self.spec.name,
            "status": self.status.value,
            "submit_time": self.submit_time,
            "start_time": self.start_time,
            "end_time": self.end_time,
            "error": self.error,
            "breakdowns": [s.breakdown() for s in self.slices],
        }
