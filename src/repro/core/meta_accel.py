"""Meta-accelerator: heterogeneous task -> accelerator-kind placement.

Paper §3: "a convolution layer task is executed on GPU, and a fully
connected layer task is executed on FPGA. We call such a set of accelerators
a meta accelerator." The TPU-native analogue is *stage placement*: the tasks
of one job land on sub-slices of different accelerator kinds (or disjoint
device blocks of one kind), and activations hop between sub-slices over the
interconnect (the FiC-network edge; measured here as transfer bytes/time).

Example use: whisper encoder on sub-slice A, decoder on sub-slice B
(examples/meta_accelerator.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.pool import DevicePool
from repro.core.slice import Slice


@dataclasses.dataclass
class StageSpec:
    name: str
    kind: Optional[str]
    n_devices: int
    mesh_shape: Optional[Tuple[int, ...]] = None
    axis_names: Optional[Tuple[str, ...]] = None
    stage_fn: Optional[Callable] = None  # (slice, inputs) -> outputs


class MetaAccelerator:
    """Co-allocates one sub-slice per stage and runs the stage pipeline."""

    def __init__(self, pool: DevicePool):
        self.pool = pool
        self.transfer_log: List[dict] = []

    def allocate(self, stages: Sequence[StageSpec]) -> List[Slice]:
        slices = []
        try:
            for st in stages:
                s = Slice(name=f"meta/{st.name}", pool=self.pool,
                          n_devices=st.n_devices, mesh_shape=st.mesh_shape,
                          axis_names=st.axis_names, kind=st.kind)
                s.attach_device()
                s.launch_machine()
                slices.append(s)
        except Exception:
            for s in slices:
                if s.lease is not None:
                    self.pool.release(s.lease)
            raise
        return slices

    def run_pipeline(self, stages: Sequence[StageSpec],
                     slices: Sequence[Slice], inputs: Any) -> Any:
        """Run stages in order, transferring activations between
        sub-slices (the disaggregated-network hop)."""
        x = inputs
        for st, s in zip(stages, slices):
            x = self._transfer_to(s, x, st.name)
            if st.stage_fn is not None:
                x = st.stage_fn(s, x)
        return x

    def release(self, slices: Sequence[Slice]):
        for s in slices:
            if s.lease is not None:
                self.pool.release(s.lease)
                s.lease = None
            s.mesh = None

    # ------------------------------------------------------------------
    def _transfer_to(self, dst: Slice, x: Any, stage: str) -> Any:
        """Move activations onto the destination sub-slice, logging the
        hop (bytes, seconds) — the ExpEther/FiC-network edge."""
        import jax

        if dst.mesh is None or x is None:
            return x
        t0 = time.perf_counter()
        target = jax.sharding.NamedSharding(
            dst.mesh, jax.sharding.PartitionSpec())
        moved = jax.tree.map(lambda a: jax.device_put(a, target), x)
        jax.block_until_ready(moved)
        # a.nbytes reads shape/dtype metadata only; np.asarray(a) would
        # copy every activation leaf back to the host just to count bytes
        nbytes = sum(a.nbytes for a in jax.tree.leaves(moved))
        self.transfer_log.append({
            "stage": stage, "bytes": int(nbytes),
            "seconds": time.perf_counter() - t0,
        })
        return moved
