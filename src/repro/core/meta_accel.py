"""Meta-accelerator: heterogeneous task -> accelerator-kind placement.

Paper §3: "a convolution layer task is executed on GPU, and a fully
connected layer task is executed on FPGA. We call such a set of accelerators
a meta accelerator." The TPU-native analogue is *stage placement*: the tasks
of one job land on sub-slices of different accelerator kinds (or disjoint
device blocks of one kind), and activations hop between sub-slices over the
interconnect (the FiC-network edge; measured here as transfer bytes/time).

Data plane (DESIGN.md §5): the paper's §2 measurement is that the
disaggregation penalty is traffic-proportional, not compute-proportional —
so the hop cost can be *hidden* by overlapping transfer with compute.
``run_pipeline(..., microbatches=k)`` splits the batch into k microbatches
and runs them GPipe-style through the stage chain: every hop and every
stage compute is its own worker thread joined by bounded ``PipelineQueue``s
(the prefetch pattern from data/pipeline.py), so while stage *i* computes
microbatch *m*, the hop for *m+1* is already in flight. ``LinkModel``
emulates an ExpEther-class edge on hosts whose devices share a local bus,
making the overlap measurable anywhere (benchmarks/pipeline_overlap.py).

Example use: whisper encoder on sub-slice A, decoder on sub-slice B
(examples/meta_accelerator.py); disaggregated prefill/decode serving
(launch/serve.py --microbatches).
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.pool import AllocationError, DevicePool
from repro.core.slice import Slice
from repro.data.pipeline import PipelineQueue


@dataclasses.dataclass
class StageSpec:
    name: str
    kind: Optional[str]
    n_devices: int
    mesh_shape: Optional[Tuple[int, ...]] = None
    axis_names: Optional[Tuple[str, ...]] = None
    stage_fn: Optional[Callable] = None  # (slice, inputs) -> outputs
    # Outputs of this stage are treated as exclusively-owned activations:
    # the hop into the next stage donates their buffers to device_put,
    # killing the redundant copy. A stage that returns shared/persistent
    # arrays (params, a cache reused across calls) must opt out.
    donate_activations: bool = True


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """Emulated disaggregation edge. The paper measures ExpEther at ~20%
    of local PCIe bandwidth (§2); on hosts where all sub-slices share one
    physical bus the hop would otherwise be free, so transfers optionally
    pay ``latency + bytes/bandwidth`` of modeled wire time. The delay is
    served by the hop worker that owns the edge — concurrent with every
    other hop and stage — so overlap behaves like real DMA hardware.

    When several transfers are in flight on *one* edge they share its
    bandwidth: MetaAccelerator routes each hop through a per-edge
    ``_FairShareEdge`` where n concurrent streams each drain at
    bandwidth/n (fluid-flow fair share), instead of each being timed as
    if alone on the wire."""

    gbytes_per_s: float = 4.0
    latency_s: float = 0.0

    def delay_s(self, nbytes: int) -> float:
        """Uncontended wire time (single stream on the edge)."""
        return self.latency_s + nbytes / (self.gbytes_per_s * 1e9)


class _FairShareEdge:
    """Fluid-flow model of one fabric edge: every in-flight stream drains
    at bandwidth / n_active, re-weighted whenever a stream joins or
    finishes. ``settle`` advances the fluid state piecewise (a stream
    finishing mid-interval changes the rate for the rest); ``wait``
    blocks a hop worker until its stream has drained, re-projecting on
    every membership change (joiners notify the condition)."""

    def __init__(self, bytes_per_s: float):
        self.bps = bytes_per_s
        self.cond = threading.Condition()
        self.streams: Dict[int, float] = {}   # sid -> bytes remaining
        self.last: Optional[float] = None
        self._ids = itertools.count()

    def _settle(self, now: float):
        while self.streams and now > self.last:
            n = len(self.streams)
            rate = self.bps / n
            to_first_drain = min(self.streams.values()) / rate
            dt = min(to_first_drain, now - self.last)
            drained = []
            for sid in self.streams:
                self.streams[sid] -= dt * rate
                if self.streams[sid] <= 1e-9:
                    drained.append(sid)
            for sid in drained:
                del self.streams[sid]
            self.last += dt
        self.last = now

    def start(self, nbytes: int) -> int:
        with self.cond:
            now = time.perf_counter()
            if self.last is None:
                self.last = now
            self._settle(now)
            sid = next(self._ids)
            self.streams[sid] = float(max(nbytes, 1))
            self.cond.notify_all()    # waiters re-project at the new n
            return sid

    def wait(self, sid: int):
        with self.cond:
            while True:
                self._settle(time.perf_counter())
                if sid not in self.streams:
                    return
                projected = (self.streams[sid] * len(self.streams)
                             / self.bps)
                self.cond.wait(timeout=projected)


def split_microbatches(inputs: Any, k: int) -> List[Any]:
    """Split every array leaf of ``inputs`` along axis 0 (the batch axis)
    into ``k`` near-even chunks — uneven batches allowed, array_split
    boundaries. Non-array leaves are replicated into every chunk; every
    array leaf must agree on the batch size."""
    import jax

    leaves, treedef = jax.tree.flatten(inputs)
    is_batched = [hasattr(a, "shape") and getattr(a, "ndim", 0) >= 1
                  for a in leaves]
    sizes = {a.shape[0] for a, b in zip(leaves, is_batched) if b}
    if len(sizes) != 1:
        raise ValueError(
            "microbatching needs exactly one batch axis across array "
            f"leaves; got dim-0 sizes {sorted(sizes)}")
    batch = sizes.pop()
    if not 1 <= k <= batch:
        raise ValueError(f"microbatches={k} not in [1, batch={batch}]")
    base, extra = divmod(batch, k)
    chunks, off = [], 0
    for i in range(k):
        n = base + (1 if i < extra else 0)
        sl = slice(off, off + n)
        chunks.append(jax.tree.unflatten(treedef, [
            a[sl] if b else a for a, b in zip(leaves, is_batched)]))
        off += n
    return chunks


def concat_microbatches(chunks: Sequence[Any]) -> Any:
    """Inverse of split_microbatches over stage outputs: concatenate every
    leaf along axis 0 (outputs must be arrays with a batch axis)."""
    import jax
    import jax.numpy as jnp

    flat = [jax.tree.flatten(c) for c in chunks]
    treedef = flat[0][1]
    if any(td != treedef for _, td in flat[1:]):
        raise ValueError(
            "stage outputs differ in pytree structure across microbatches")
    leaves = [jnp.concatenate(parts, axis=0)
              for parts in zip(*(lv for lv, _ in flat))]
    return jax.tree.unflatten(treedef, leaves)


class MetaAccelerator:
    """Co-allocates one sub-slice per stage and runs the stage pipeline."""

    def __init__(self, pool: DevicePool, link: Optional[LinkModel] = None,
                 transfer_log_maxlen: int = 4096):
        self.pool = pool
        self.link = link
        # Bounded + lock-guarded: pipelined hop workers append from their
        # own threads; exact running totals survive deque eviction.
        self.transfer_log: "collections.deque" = collections.deque(
            maxlen=transfer_log_maxlen)
        self._log_lock = threading.Lock()
        self._totals = {"hops": 0, "bytes": 0, "seconds": 0.0}
        # one fair-share bandwidth model per destination slice (= fabric
        # edge): concurrent in-flight hops split the modeled wire
        self._edges: Dict[int, _FairShareEdge] = {}

    def _edge_for(self, dst: Slice) -> "_FairShareEdge":
        with self._log_lock:
            edge = self._edges.get(id(dst))
            if edge is None:
                edge = _FairShareEdge(self.link.gbytes_per_s * 1e9)
                self._edges[id(dst)] = edge
            return edge

    def allocate(self, stages: Sequence[StageSpec]) -> List[Slice]:
        # gang feasibility first (one O(#kinds) index query): a stage set
        # that cannot co-allocate fails before any attach/rollback churn
        # against a possibly-shared pool
        need: Dict[Optional[str], int] = {}
        for st in stages:
            need[st.kind] = need.get(st.kind, 0) + st.n_devices
        if not self.pool.can_allocate_many(need):
            raise AllocationError(
                f"meta-accelerator gang infeasible: need {need}, "
                f"free {self.pool.free_count()}")
        slices = []
        try:
            for st in stages:
                s = Slice(name=f"meta/{st.name}", pool=self.pool,
                          n_devices=st.n_devices, mesh_shape=st.mesh_shape,
                          axis_names=st.axis_names, kind=st.kind)
                # appended before attach so the rollback below also tears
                # down a stage that fails between attach and launch
                # (teardown is a no-op for a CREATED slice)
                slices.append(s)
                s.attach_device()
                s.launch_machine()
        except Exception:
            for s in slices:
                s.teardown()
            raise
        return slices

    def run_pipeline(self, stages: Sequence[StageSpec],
                     slices: Sequence[Slice], inputs: Any, *,
                     microbatches: int = 1, queue_depth: int = 2) -> Any:
        """Run stages in order, transferring activations between
        sub-slices (the disaggregated-network hop).

        ``microbatches=1`` is the serial path: each hop is paid in full on
        the critical path. ``microbatches=k`` splits the batch along axis
        0 and pipelines the chunks (DESIGN.md §5); the result is the
        concatenation of the chunk outputs, bit-exact vs. serial for
        batch-row-independent stage functions."""
        if microbatches <= 1:
            import jax
            x = inputs
            for st, s in zip(stages, slices):
                x = self.transfer(s, x, st.name)
                if st.stage_fn is not None:
                    x = st.stage_fn(s, x)
            # drain like the microbatched path does, so both return
            # settled arrays and serial-vs-pipelined timings compare the
            # same amount of completed work
            jax.block_until_ready(x)
            return x
        return self._run_microbatched(stages, slices, inputs,
                                      microbatches, queue_depth)

    def release(self, slices: Sequence[Slice]):
        """Tear every stage down through the slice lifecycle
        (detach_device + destroy_machine), so stages end DESTROYED with
        their transitions timed — not as dead ATTACHED husks. Also drops
        the slices' fair-share edge models: id() can be recycled, and a
        new slice must never inherit a dead edge's stream state."""
        for s in slices:
            s.teardown()
        with self._log_lock:
            for s in slices:
                self._edges.pop(id(s), None)

    # -- single-hop API ----------------------------------------------------
    def transfer(self, dst: Slice, x: Any, stage: str = "hop", *,
                 donate: bool = False) -> Any:
        """Public blocking single-hop transfer: move activations onto
        ``dst`` and log the hop (bytes, seconds) — the ExpEther/FiC edge.
        Returns ``x`` untouched when ``dst`` has no mesh."""
        moved, complete = self.transfer_async(dst, x, stage, donate=donate)
        complete()
        return moved

    def transfer_async(self, dst: Slice, x: Any, stage: str = "hop", *,
                       donate: bool = False):
        """Non-blocking hop: issue the device_put and return
        ``(moved, complete)`` immediately. ``complete()`` serves any
        modeled wire time, waits for the data to land, and logs the hop —
        the pipeline calls it from hop workers so per-hop timing stays off
        every compute thread."""
        import jax

        if dst.mesh is None or x is None:
            return x, (lambda: None)
        t0 = time.perf_counter()
        target = dst.replicated_sharding()
        moved = jax.tree.map(
            lambda a: jax.device_put(a, target, donate=donate), x)
        # a.nbytes reads shape/dtype metadata only; np.asarray(a) would
        # copy every activation leaf back to the host just to count bytes
        nbytes = sum(a.nbytes for a in jax.tree.leaves(moved))
        # the stream occupies the edge from issue time: a second hop
        # overlapping this one shares the modeled bandwidth immediately
        edge = sid = None
        if self.link is not None:
            edge = self._edge_for(dst)
            sid = edge.start(nbytes)
        done = [False]

        def complete():
            if done[0]:
                return
            done[0] = True
            if edge is not None:
                edge.wait(sid)
                # uncontended floor keeps single-stream timing identical
                # to the pre-fair-share model (latency + bytes/bw)
                remaining = (t0 + self.link.delay_s(nbytes)
                             - time.perf_counter())
                if remaining > 0:
                    time.sleep(remaining)
            jax.block_until_ready(moved)
            self._log_hop(stage, nbytes, time.perf_counter() - t0)

        return moved, complete

    def transfer_totals(self) -> Dict[str, float]:
        """Exact running aggregate over *all* hops ever logged — the
        bounded transfer_log may have evicted old entries."""
        with self._log_lock:
            return dict(self._totals)

    # retained for callers of the old private API
    def _transfer_to(self, dst: Slice, x: Any, stage: str) -> Any:
        return self.transfer(dst, x, stage)

    def _log_hop(self, stage: str, nbytes: int, seconds: float):
        with self._log_lock:
            self.transfer_log.append({
                "stage": stage, "bytes": int(nbytes), "seconds": seconds})
            self._totals["hops"] += 1
            self._totals["bytes"] += int(nbytes)
            self._totals["seconds"] += seconds

    # -- pipelined data plane ----------------------------------------------
    def _run_microbatched(self, stages: Sequence[StageSpec],
                          slices: Sequence[Slice], inputs: Any,
                          k: int, depth: int) -> Any:
        """GPipe-style schedule over 2S resources — S hops + S stage
        computes, each a worker thread, joined by bounded queues:

            hop_0 -> comp_0 -> hop_1 -> comp_1 -> ... -> results

        A hop worker owns one fabric edge: it issues the non-blocking
        device_put (donating the producing stage's activation buffers),
        serves the modeled wire time, and logs completion — all off the
        compute threads. First worker error stops every queue and is
        re-raised here; order is preserved end to end so the concatenated
        result matches the serial path bit for bit."""
        import jax

        chunks = split_microbatches(inputs, k)
        n = len(stages)
        stop = threading.Event()
        errors: List[BaseException] = []
        err_lock = threading.Lock()
        hop_q = [PipelineQueue(depth, stop=stop) for _ in range(n)]
        comp_q = [PipelineQueue(depth, stop=stop) for _ in range(n)]
        results: List[Any] = [None] * k

        def fail(e: BaseException):
            with err_lock:
                errors.append(e)
            stop.set()

        def hop_worker(i: int):
            try:
                donate = i > 0 and stages[i - 1].donate_activations
                for m, x in hop_q[i]:
                    moved, complete = self.transfer_async(
                        slices[i], x, stages[i].name, donate=donate)
                    complete()
                    if not comp_q[i].put((m, moved)):
                        return
                comp_q[i].close()
            except BaseException as e:  # noqa: BLE001
                fail(e)

        def comp_worker(i: int):
            try:
                for m, x in comp_q[i]:
                    y = (stages[i].stage_fn(slices[i], x)
                         if stages[i].stage_fn is not None else x)
                    if i + 1 < n:
                        if not hop_q[i + 1].put((m, y)):
                            return
                    else:
                        results[m] = y
                if i + 1 < n:
                    hop_q[i + 1].close()
            except BaseException as e:  # noqa: BLE001
                fail(e)

        threads = [threading.Thread(target=hop_worker, args=(i,),
                                    daemon=True, name=f"meta-hop-{i}")
                   for i in range(n)]
        threads += [threading.Thread(target=comp_worker, args=(i,),
                                     daemon=True, name=f"meta-comp-{i}")
                    for i in range(n)]
        for t in threads:
            t.start()
        try:
            for m, c in enumerate(chunks):
                if not hop_q[0].put((m, c)):
                    break
            hop_q[0].close()
            for t in threads:
                t.join()
        finally:
            stop.set()
        if errors:
            raise errors[0]
        out = concat_microbatches(results)
        jax.block_until_ready(out)  # drain: callers get settled arrays
        return out
