from repro.core.pool import (DevicePool, Lease, DeviceInfo,  # noqa: F401
                             AllocationError, FreeRunIndex)
from repro.core.slice import Slice, SliceState  # noqa: F401
from repro.core.job import (JobSpec, TaskSpec, JobStatus,  # noqa: F401
                            Preempted)
from repro.core.rm import FlowOSRM  # noqa: F401
from repro.core.meta_accel import (LinkModel, MetaAccelerator,  # noqa: F401
                                   StageSpec)
from repro.core.elastic import ElasticController  # noqa: F401
