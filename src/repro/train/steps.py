"""Train / prefill / decode step builders + ShapeDtypeStruct input specs.

Every step is a pure function suitable for ``jax.jit`` with explicit
in/out shardings derived from the active sharding policy. ``input_specs``
returns ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no
allocation) for the dry-run.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig, ShapeConfig
from repro.models.registry import Model
from repro.optim.adamw import AdamW, OptState
from repro.parallel.sharding import (AxisRules, axis_rules,
                                     sanitize_tree_specs, tree_specs)


class TrainState(NamedTuple):
    params: Any
    opt: OptState


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def cast_params(cfg: ModelConfig, params):
    """One-time fp32 -> compute-dtype cast at step entry. Casting *before*
    any use means SPMD's FSDP all-gathers move bf16, not fp32 — observed 2x
    on every weight collective when the cast sat after the gather."""
    dt = jnp.dtype(cfg.dtype)
    return jax.tree.map(
        lambda p: p.astype(dt) if p.dtype == jnp.float32 else p, params)


def make_train_step(model: Model, optimizer: AdamW, rules: AxisRules,
                    aux_weight: float = 0.01, n_microbatches: int = 1):
    """Training step; with n_microbatches > 1 the global batch is split on
    the batch axis and gradients are accumulated in fp32 across a
    lax.scan — peak activation memory scales ~1/n at unchanged collective
    volume (grad accumulation is local)."""
    from repro.train.losses import next_token_loss_from_hidden
    cfg = model.cfg

    def loss_and_grad(params, batch):
        def loss_fn(p):
            with axis_rules(rules):
                params_c = cast_params(cfg, p)
                hidden, aux = model.apply_hidden(cfg, params_c, batch)
                loss = next_token_loss_from_hidden(
                    cfg, params_c["embed"], hidden, batch["tokens"])
            return loss + aux_weight * aux, (loss, aux)
        return jax.grad(loss_fn, has_aux=True)(params)

    def train_step(state: TrainState, batch):
        if n_microbatches <= 1:
            grads, (loss, aux) = loss_and_grad(state.params, batch)
        else:
            micro = jax.tree.map(
                lambda a: a.reshape((n_microbatches,
                                     a.shape[0] // n_microbatches)
                                    + a.shape[1:]), batch)

            def acc_body(carry, mb):
                g_acc, l_acc, a_acc = carry
                g, (loss_mb, a) = loss_and_grad(state.params, mb)
                g_acc = jax.tree.map(
                    lambda x, y: x + y.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss_mb, a_acc + a), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, loss, aux), _ = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32),
                           jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)
            loss = loss / n_microbatches
            aux = aux / n_microbatches
        with axis_rules(rules):
            new_params, new_opt, om = optimizer.update(
                grads, state.opt, state.params)
        metrics = {"loss": loss, "aux_loss": aux, **om}
        return TrainState(new_params, new_opt), metrics

    return train_step


def make_prefill_step(model: Model, rules: AxisRules):
    cfg = model.cfg

    def prefill_step(params, batch):
        with axis_rules(rules):
            logits, _ = model.apply(cfg, cast_params(cfg, params), batch)
        return logits

    return prefill_step


def make_serve_step(model: Model, rules: AxisRules):
    """One decode step: (params, cache, tokens) -> (logits, new cache)."""
    cfg = model.cfg

    def serve_step(params, cache, tokens):
        with axis_rules(rules):
            return model.decode_step(cfg, cast_params(cfg, params), cache,
                                     tokens)

    return serve_step


# ---------------------------------------------------------------------------
# ShapeDtypeStruct specs for the dry-run
# ---------------------------------------------------------------------------

def batch_struct(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStructs of the model inputs for one global batch."""
    B, S = shape.global_batch, shape.seq_len
    if shape.is_decode:
        specs = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        return specs
    specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.family == "audio":
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_vision_patches, cfg.d_model), jnp.bfloat16)
    return specs


def batch_spec_tree(cfg: ModelConfig, shape: ShapeConfig, rules: AxisRules):
    """PartitionSpecs matching batch_struct."""
    b = rules.spec(("batch", None))
    out = {"tokens": b}
    if not shape.is_decode:
        if cfg.family == "audio":
            out["frames"] = rules.spec(("batch", None, None))
        if cfg.family == "vlm":
            out["vision_embeds"] = rules.spec(("batch", None, None))
        out["tokens"] = rules.spec(("batch", "seq"))
    return out


def params_struct(model: Model):
    """Parameter ShapeDtypeStructs via eval_shape (no allocation)."""
    return jax.eval_shape(
        functools.partial(model.init, model.cfg), jax.random.PRNGKey(0))


def cache_struct(model: Model, shape: ShapeConfig, dtype=jnp.bfloat16):
    assert model.init_cache is not None
    return jax.eval_shape(
        functools.partial(model.init_cache, model.cfg, shape.global_batch,
                          shape.seq_len, dtype=dtype))


def state_specs(model: Model, rules: AxisRules):
    """(param specs, opt-state specs) as PartitionSpec pytrees."""
    p_axes = model.param_axes(model.cfg)
    p_specs = tree_specs(rules, p_axes)
    opt_specs = OptState(step=P(), mu=p_specs, nu=p_specs)
    return p_specs, opt_specs


def cache_specs(model: Model, rules: AxisRules):
    assert model.cache_axes is not None
    return tree_specs(rules, model.cache_axes(model.cfg))


def input_specs(model: Model, shape: ShapeConfig, rules: AxisRules):
    """Everything the dry-run needs to lower a step for (arch, shape):

    returns (kind, args_structs, in_shardings) where args match the step
    function signature.
    """
    cfg = model.cfg
    mesh = rules.mesh

    def as_shard(tree):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s), tree,
            is_leaf=lambda x: isinstance(x, P))

    p_struct = params_struct(model)
    p_specs, opt_specs = state_specs(model, rules)
    p_specs = sanitize_tree_specs(mesh, p_specs, p_struct)
    batch = batch_struct(cfg, shape)
    b_specs = batch_spec_tree(cfg, shape, rules)
    b_specs = sanitize_tree_specs(mesh, b_specs, batch)

    if shape.kind == "train":
        opt_struct = jax.eval_shape(
            lambda p: AdamW().init(p), p_struct)
        opt_specs = OptState(step=P(), mu=p_specs, nu=p_specs)
        state = TrainState(p_struct, opt_struct)
        state_spec = TrainState(p_specs, opt_specs)
        return ("train", (state, batch),
                (as_shard(state_spec), as_shard(b_specs)))
    if shape.kind == "prefill":
        return ("prefill", (p_struct, batch),
                (as_shard(p_specs), as_shard(b_specs)))
    # decode
    c_struct = cache_struct(model, shape)
    c_specs = cache_specs(model, rules)
    c_specs = sanitize_tree_specs(mesh, c_specs, c_struct)
    return ("decode", (p_struct, c_struct, batch["tokens"]),
            (as_shard(p_specs), as_shard(c_specs),
             as_shard(b_specs["tokens"])))
