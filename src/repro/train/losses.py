"""Losses. The next-token CE is computed in vocab chunks with an online
logsumexp so the full (B, S, V) logits tensor is never materialized — at
150k-260k vocab this is the difference between fitting and not fitting
(e.g. gemma3: 4.3 GB of logits per device per microbatch avoided).

The chunk body is wrapped in jax.checkpoint so backward recomputes the
chunk logits instead of keeping all of them alive.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def _unembed_matrix(cfg: ModelConfig, embed_params):
    """(d, V) unembedding weights."""
    if cfg.tie_embeddings:
        return embed_params["embedding"].T
    return embed_params["unembed"]


def chunked_ce_loss(cfg: ModelConfig, embed_params, hidden, targets,
                    vocab_chunk: int = 16_384):
    """hidden: (B, S, d) final hidden states aligned with targets (B, S).
    Returns mean CE in fp32."""
    from repro.parallel.sharding import shard

    w = _unembed_matrix(cfg, embed_params)  # (d, V)
    d, V = w.shape
    n_chunks = -(-V // vocab_chunk)
    Vp = n_chunks * vocab_chunk
    if Vp != V:
        w = jnp.pad(w, ((0, 0), (0, Vp - V)))
    w_chunks = w.T.reshape(n_chunks, vocab_chunk, d)  # (n, c, d)
    # Replicate the weight chunks and shard the *sequence* over the model
    # axis instead (Megatron-SP-style LM head). The alternatives are worse:
    # vocab-sharded chunks make the backward dx a partial-sum all-reduce of
    # (B, S, d) per chunk (observed: ~10 GB/step), and d(FSDP)-sharded
    # chunks make the forward logits a partial-sum all-reduce.
    w_chunks = shard(w_chunks, None, None, None)

    x = shard(hidden, "batch", "seq_ce", None)
    B, S, _ = x.shape
    tgt = shard(targets, "batch", "seq_ce")

    @jax.checkpoint
    def chunk_body(carry, inp):
        m, s, gold = carry
        w_c, idx = inp  # (c, d), ()
        logits = jnp.einsum("bsd,cd->bsc", x, w_c,
                            preferred_element_type=jnp.float32)
        col = idx * vocab_chunk + jnp.arange(vocab_chunk)
        logits = jnp.where(col[None, None, :] < V, logits, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        s = s * jnp.exp(m - m_new) + jnp.sum(
            jnp.exp(logits - m_new[..., None]), axis=-1)
        local = tgt - idx * vocab_chunk
        in_chunk = (local >= 0) & (local < vocab_chunk)
        picked = jnp.take_along_axis(
            logits, jnp.clip(local, 0, vocab_chunk - 1)[..., None],
            axis=-1)[..., 0]
        gold = gold + jnp.where(in_chunk, picked, 0.0)
        return (m_new, s, gold), None

    init = (jnp.full((B, S), -jnp.inf, jnp.float32),
            jnp.zeros((B, S), jnp.float32),
            jnp.zeros((B, S), jnp.float32))
    (m, s, gold), _ = jax.lax.scan(
        chunk_body, init, (w_chunks, jnp.arange(n_chunks)))
    lse = m + jnp.log(s)
    return jnp.mean(lse - gold)


def next_token_loss_from_hidden(cfg: ModelConfig, embed_params, hidden,
                                tokens, vocab_chunk: int = 16_384):
    """Shift-by-one CE: hidden positions [0, S-1) predict tokens [1, S)."""
    return chunked_ce_loss(cfg, embed_params, hidden[:, :-1], tokens[:, 1:],
                           vocab_chunk=vocab_chunk)
