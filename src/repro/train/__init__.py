from repro.train.steps import (  # noqa: F401
    TrainState,
    make_train_step,
    make_serve_step,
    make_prefill_step,
    input_specs,
    state_specs,
)
