"""Flash-decode (split-KV) kernel: one query token against a long KV cache.

The KV sequence is split into blocks; grid (batch, q_head, kv_blocks) with
the kv axis sequential and (m, l, acc) in VMEM scratch — the kernel twin of
the split-KV sharding the policy uses for decode shapes. Per-batch cache
length (kv_len) and sliding windows mask at block granularity, and blocks
entirely past the valid region are skipped.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(kvlen_ref, qpos_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale, window, softcap, bkv, n_kv):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = kvlen_ref[b]
    q_pos = qpos_ref[0]
    k_start = j * bkv
    run = k_start < kv_len
    if window is not None:
        run = run & (k_start + bkv - 1 >= q_pos - (window - 1))

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (1, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (bkv, D)
        v = v_ref[0, 0]                              # (bkv, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (1, bkv)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, bkv), 1)
        s = jnp.where(k_pos < kv_len, s, NEG_INF)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        if window is not None:
            s = jnp.where(q_pos - k_pos < window, s, NEG_INF)
        m_prev = m_ref[0, 0]
        l_prev = l_ref[0, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)      # (1, D)
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[0, 0] = m_new
        l_ref[0, 0] = l_new

    @pl.when(j == n_kv - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[0, 0], 1e-30)).astype(o_ref.dtype)


def decode_attention_fwd(q, k, v, kv_len, q_pos, *,
                         window: Optional[int] = None,
                         softcap: Optional[float] = None,
                         scale: Optional[float] = None,
                         bkv: int = 512, interpret: bool = True):
    """q: (B, Hq, 1, D); k, v: (B, Hkv, T, D); kv_len: (B,) int32;
    q_pos: (1,) int32. Returns (B, Hq, 1, D)."""
    B, Hq, _, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    bkv = min(bkv, T)
    assert T % bkv == 0
    n_kv = T // bkv

    kernel = functools.partial(_kernel, scale=scale, window=window,
                               softcap=softcap, bkv=bkv, n_kv=n_kv)
    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        kernel,
        grid=(B, Hq, n_kv),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # kv_len
            pl.BlockSpec(memory_space=pltpu.SMEM),  # q_pos
            pl.BlockSpec((1, 1, 1, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bkv, D), lambda b, h, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bkv, D), lambda b, h, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, 1, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len, q_pos, q, k, v)
