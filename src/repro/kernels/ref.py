"""Pure-jnp oracles for every kernel (the correctness ground truth the
interpret-mode sweeps assert against)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None,
                  softcap: Optional[float] = None,
                  scale: Optional[float] = None,
                  kv_len=None, q_pos=None):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D). Naive full-score oracle."""
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    kk = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vv = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32), kk) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    qp = (jnp.arange(Sq) if q_pos is None
          else jnp.broadcast_to(q_pos, (Sq,)))
    kp = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kp[None, :] <= qp[:, None]
    if window is not None:
        mask &= (qp[:, None] - kp[None, :]) < window
    s = jnp.where(mask[None, None], s, -1e30)
    if kv_len is not None:
        tail = kp[None, :] < kv_len[:, None]
        s = jnp.where(tail[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", p, vv)
    return out.astype(q.dtype)


def ssd_ref(x, dt, A, Bm, Cm, h0=None):
    """Sequential SSD oracle. x: (B, H, S, P); dt: (B, H, S); A: (H,);
    Bm/Cm: (B, G, S, N). Returns (y (B,H,S,P), state (B,H,N,P))."""
    B, H, S, P = x.shape
    G, N = Bm.shape[1], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)  # (B,H,S,N)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(h, t):
        decay = jnp.exp(dtf[:, :, t] * A)[..., None, None]
        h = h * decay + (dtf[:, :, t, None] * Bh[:, :, t])[..., None] \
            * xf[:, :, t, None, :]
        y = jnp.einsum("bhn,bhnp->bhp", Ch[:, :, t], h)
        return h, y

    h = jnp.zeros((B, H, N, P), jnp.float32) if h0 is None else h0
    ys = []
    for t in range(S):
        h, y = step(h, t)
        ys.append(y)
    return jnp.stack(ys, axis=2).astype(x.dtype), h


def rmsnorm_ref(x, w, *, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * w.astype(jnp.float32)).astype(x.dtype)


def moe_ffn_ref(x, wg, wu, wd):
    dt = x.dtype
    g = jnp.einsum("ecd,edf->ecf", x, wg.astype(dt))
    u = jnp.einsum("ecd,edf->ecf", x, wu.astype(dt))
    h = jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)
    return jnp.einsum("ecf,efd->ecd", h.astype(dt), wd.astype(dt))
