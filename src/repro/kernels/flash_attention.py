"""Flash attention (FA2-style) forward kernel for TPU.

Tiling: grid (batch, q_heads, q_blocks, kv_blocks); the kv dimension is the
sequential minor axis with (m, l, acc) carried in VMEM scratch. GQA is
handled in the K/V index maps (kv head = q head // group); causal and
sliding-window masking are additive, and fully-masked kv blocks are skipped
with ``pl.when`` (block-index arithmetic, no wasted MXU issue).

Block sizes default to (bq, bkv) = (256, 512) with D padded to 128 lanes by
the caller — MXU-aligned.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: Optional[int],
            softcap: Optional[float], bq: int, bkv: int, n_kv: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = i * bq
    k_start = j * bkv
    run = jnp.bool_(True)
    if causal:
        run = run & (k_start <= q_start + bq - 1)
    if window is not None:
        run = run & (k_start + bkv - 1 >= q_start - (window - 1))

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bkv, D)
        v = v_ref[0, 0]                      # (bkv, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bkv)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        if causal:
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        if window is not None:
            s = jnp.where(q_pos - k_pos < window, s, NEG_INF)

        m_prev = m_ref[...][:, 0]
        l_prev = l_ref[...][:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new[:, None]
        l_ref[...] = l_new[:, None]

    @pl.when(j == n_kv - 1)
    def _finish():
        lsum = l_ref[...][:, 0]
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(lsum, 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        scale: Optional[float] = None,
                        bq: int = 256, bkv: int = 512,
                        interpret: bool = True):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D). Returns (B, Hq, Sq, D)."""
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    bq = min(bq, Sq)
    bkv = min(bkv, Skv)
    assert Sq % bq == 0 and Skv % bkv == 0
    nq, n_kv = Sq // bq, Skv // bkv

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        bq=bq, bkv=bkv, n_kv=n_kv)

    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bkv, D), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bkv, D), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            _vmem((bq, 1), jnp.float32),   # m
            _vmem((bq, 1), jnp.float32),   # l
            _vmem((bq, D), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
