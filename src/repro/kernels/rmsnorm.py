"""Fused RMSNorm kernel: one HBM pass (read x, write y) per row block
instead of the unfused mean-square / rsqrt / scale chain."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)       # (br, D)
    w = w_ref[...].astype(jnp.float32)       # (D,)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w[None, :]
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_fwd(x, w, *, eps: float = 1e-6, block_rows: int = 256,
                interpret: bool = True):
    """x: (R, D); w: (D,). Returns (R, D)."""
    R, D = x.shape
    br = min(block_rows, R)
    while R % br != 0:
        br //= 2
    br = max(br, 1)
    kernel = functools.partial(_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(R // br,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
    )(x, w)
