"""Paged flash-decode kernel: one query token against a *paged* KV cache.

The serving plane (DESIGN.md §10) stores KV in fixed-size pages scattered
through one HBM block pool; each sequence owns an ordered page list (its
page table). This kernel extends decode_attention.py's split-KV grid
(batch, q_head, kv_blocks) by routing the kv-block axis through the page
table with scalar prefetch: block j of sequence b streams page
``page_table[b, j]`` out of the pool, so the gather costs the same DMA the
contiguous kernel pays — no host-side re-packing, no copy into a
per-sequence buffer.

Differences from the contiguous kernel, both forced by continuous
batching: ``q_pos`` is per-sequence (every lane decodes at its own
position), and the KV extent is ``page_table.shape[1] * page_size``
logical tokens regardless of where the pages physically live.

Page-table slots at or past a sequence's live extent must still hold a
*valid* page id (the pool reserves page 0 as the null page): the block is
masked out of the softmax by ``kv_len``, but its index is prefetched
before the mask is known.

``paged_attention_jnp`` is the pure-jnp twin (gather + masked softmax)
the CPU serving engine jits; interpret-mode tests pin kernel == twin ==
contiguous reference.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(tbl_ref, kvlen_ref, qpos_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale, window, softcap, ps, n_pages):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = kvlen_ref[b]
    q_pos = qpos_ref[b]
    k_start = j * ps
    run = k_start < kv_len
    if window is not None:
        run = run & (k_start + ps - 1 >= q_pos - (window - 1))

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (1, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (ps, D)
        v = v_ref[0, 0]                              # (ps, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (1, ps)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
        s = jnp.where(k_pos < kv_len, s, NEG_INF)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        if window is not None:
            s = jnp.where(q_pos - k_pos < window, s, NEG_INF)
        m_prev = m_ref[0, 0]
        l_prev = l_ref[0, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)      # (1, D)
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[0, 0] = m_new
        l_ref[0, 0] = l_new

    @pl.when(j == n_pages - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[0, 0], 1e-30)).astype(o_ref.dtype)


def paged_decode_attention_fwd(q, k_pages, v_pages, page_table, kv_len,
                               q_pos, *,
                               window: Optional[int] = None,
                               softcap: Optional[float] = None,
                               scale: Optional[float] = None,
                               interpret: bool = True):
    """q: (B, Hq, 1, D); k_pages, v_pages: (P, Hkv, page_size, D);
    page_table: (B, max_pages) int32, every slot a valid page id (pad with
    the null page 0); kv_len, q_pos: (B,) int32. Returns (B, Hq, 1, D)."""
    B, Hq, _, D = q.shape
    _, Hkv, ps, _ = k_pages.shape
    G = Hq // Hkv
    n_pages = page_table.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    kernel = functools.partial(_kernel, scale=scale, window=window,
                               softcap=softcap, ps=ps, n_pages=n_pages)
    from jax.experimental.pallas import tpu as pltpu

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,   # page_table, kv_len, q_pos
        grid=(B, Hq, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, 1, D),
                         lambda b, h, j, tbl, kvl, qp: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, ps, D),
                         lambda b, h, j, tbl, kvl, qp:
                         (tbl[b, j], h // G, 0, 0)),
            pl.BlockSpec((1, 1, ps, D),
                         lambda b, h, j, tbl, kvl, qp:
                         (tbl[b, j], h // G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, D),
                               lambda b, h, j, tbl, kvl, qp: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, 1, D), q.dtype),
        interpret=interpret,
    )(page_table, kv_len, q_pos, q, k_pages, v_pages)


def gather_kv(pages, page_table):
    """(P, Hkv, ps, D) pages + (B, max_pages) table -> contiguous
    (B, Hkv, max_pages*ps, D) — the logical cache view a sequence sees."""
    g = pages[page_table]                       # (B, maxp, Hkv, ps, D)
    B, maxp, Hkv, ps, D = g.shape
    return g.transpose(0, 2, 1, 3, 4).reshape(B, Hkv, maxp * ps, D)


def paged_attention_jnp(q, k_pages, v_pages, page_table, kv_len, q_pos, *,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        scale: Optional[float] = None):
    """Pure-jnp twin of the paged kernel (same signature minus interpret).
    Jits to a gather + one masked softmax; the serving engine's CPU hot
    path. Per-row math is independent of every other row, which is what
    makes continuous-batching output bit-identical to static batching."""
    B, Hq, _, D = q.shape
    Hkv = k_pages.shape[1]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    k = gather_kv(k_pages, page_table)          # (B, Hkv, T, D)
    v = gather_kv(v_pages, page_table)
    T = k.shape[2]
    kk = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vv = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32), kk) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    kp = jnp.arange(T)
    mask = (kp[None, :] < kv_len[:, None]) & (kp[None, :] <= q_pos[:, None])
    if window is not None:
        mask &= (q_pos[:, None] - kp[None, :]) < window
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", p, vv)
    return out.astype(q.dtype)
