"""Fused expert-FFN kernel for the capacity-dense MoE layout.

Computes out[e] = (silu(x[e] @ wg[e]) * (x[e] @ wu[e])) @ wd[e] for every
expert without materializing the (E, C, f) hidden activations to HBM: grid
(experts, capacity blocks, f blocks) with the f axis sequential and the
(bc, d) output accumulator in VMEM. This is the MXU-shaped version of the
gather-based grouped matmul (megablox-style) specialized to the fixed
capacity buffers the dispatch layer already produces.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref, *, n_f: int):
    fi = pl.program_id(2)

    @pl.when(fi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]     # (bc, d)
    wg = wg_ref[0]   # (d, bf)
    wu = wu_ref[0]   # (d, bf)
    wd = wd_ref[0]   # (bf, d)
    g = jax.lax.dot_general(x, wg, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    u = jax.lax.dot_general(x, wu, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)          # (bc, bf)
    acc_ref[...] += jax.lax.dot_general(
        h, wd, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(fi == n_f - 1)
    def _finish():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def moe_ffn_fwd(x, wg, wu, wd, *, block_c: int = 256, block_f: int = 512,
                interpret: bool = True):
    """x: (E, C, d); wg/wu: (E, d, f); wd: (E, f, d). Returns (E, C, d)."""
    E, C, d = x.shape
    f = wg.shape[2]
    bc = min(block_c, C)
    while C % bc != 0:
        bc //= 2
    bc = max(bc, 1)
    bf = min(block_f, f)
    while f % bf != 0:
        bf //= 2
    bf = max(bf, 1)
    n_f = f // bf

    kernel = functools.partial(_kernel, n_f=n_f)
    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        kernel,
        grid=(E, C // bc, n_f),
        in_specs=[
            pl.BlockSpec((1, bc, d), lambda e, c, fi: (e, c, 0)),
            pl.BlockSpec((1, d, bf), lambda e, c, fi: (e, 0, fi)),
            pl.BlockSpec((1, d, bf), lambda e, c, fi: (e, 0, fi)),
            pl.BlockSpec((1, bf, d), lambda e, c, fi: (e, fi, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, d), lambda e, c, fi: (e, c, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, d), jnp.float32)],
        interpret=interpret,
    )(x, wg, wu, wd)
