"""Public jitted wrappers around the Pallas kernels.

Model code uses the (B, S, H, D) activation layout; kernels use
(B, H, S, D). Wrappers transpose, pad the head dim to the 128-lane MXU
boundary when needed, and choose interpret mode automatically (True off-TPU
so the same code runs in CI)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import moe_ffn as _moe
from repro.kernels import rmsnorm as _rms
from repro.kernels import ssd_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_head(x, to: int = 128):
    d = x.shape[-1]
    if d % to == 0:
        return x, d
    pad = to - d % to
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]), d


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None):
    """q: (B, S, Hq, D); k, v: (B, S, Hkv, D) — model layout."""
    import math
    scale = 1.0 / math.sqrt(q.shape[-1])
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    qt, d0 = _pad_head(qt)
    kt, _ = _pad_head(kt)
    vt, _ = _pad_head(vt)
    out = _fa.flash_attention_fwd(qt, kt, vt, causal=causal, window=window,
                                  softcap=softcap, scale=scale,
                                  interpret=_interpret())
    return out[..., :d0].transpose(0, 2, 1, 3)


def decode_attention(q, k, v, *, kv_len, q_pos,
                     window: Optional[int] = None,
                     softcap: Optional[float] = None):
    """q: (B, 1, Hq, D); k, v: (B, T, Hkv, D); kv_len: (B,); q_pos: (1,)."""
    import math
    scale = 1.0 / math.sqrt(q.shape[-1])
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    qt, d0 = _pad_head(qt)
    kt, _ = _pad_head(kt)
    vt, _ = _pad_head(vt)
    out = _dec.decode_attention_fwd(
        qt, kt, vt, kv_len.astype(jnp.int32), q_pos.astype(jnp.int32),
        window=window, softcap=softcap, scale=scale,
        interpret=_interpret())
    return out[..., :d0].transpose(0, 2, 1, 3)


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128):
    """Model layout: x (B, S, H, P); dt (B, S, H); Bm/Cm (B, S, G, N).
    Returns (y (B, S, H, P), state (B, H, N, P))."""
    xt = x.transpose(0, 2, 1, 3)
    dtt = dt.transpose(0, 2, 1)
    Bt = Bm.transpose(0, 2, 1, 3)
    Ct = Cm.transpose(0, 2, 1, 3)
    y, state = _ssd.ssd_scan_fwd(xt, dtt, A, Bt, Ct, chunk=chunk,
                                 interpret=_interpret())
    return y.transpose(0, 2, 1, 3), state


def rmsnorm(x, w, *, eps: float = 1e-6):
    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    out = _rms.rmsnorm_fwd(flat, w, eps=eps, interpret=_interpret())
    return out.reshape(shape)


def moe_ffn(x, wg, wu, wd):
    """x: (E, C, d); weights per expert. Fused expert FFN."""
    return _moe.moe_ffn_fwd(x, wg, wu, wd, interpret=_interpret())
