"""Mamba-2 SSD chunked-scan kernel.

Grid (batch, head, chunks); the chunk axis is sequential with the (N, P)
state carried in VMEM scratch. Each step does the quadratic intra-chunk
block on the MXU (C B^T with decay mask) plus the rank-1 state
injection/readout — the TPU-native shape of the state-space duality: big
matmuls inside chunks, O(N*P) recurrence between them.

Layouts: x (B, H, S, P); dt (B, H, S); A (H,); Bm/Cm (B, G, S, N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
            state_ref, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)      # (L, P)
    dt = dt_ref[0, 0].astype(jnp.float32)    # (L,)
    A = a_ref[0]                             # ()
    Bm = b_ref[0, 0].astype(jnp.float32)     # (L, N)
    Cm = c_ref[0, 0].astype(jnp.float32)     # (L, N)

    a = dt * A                               # (L,) negative
    cum = jnp.cumsum(a)
    seg_end = cum[-1]

    # intra-chunk quadratic block
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    mi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    seg = cum[:, None] - cum[None, :]
    decay = jnp.exp(jnp.where(li >= mi, seg, -jnp.inf))
    M = CB * decay * dt[None, :]
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (L, P)

    # inter-chunk: readout of the carried state
    state = state_ref[...]                   # (N, P)
    y = y + jax.lax.dot_general(Cm * jnp.exp(cum)[:, None], state,
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # state update: state = state * exp(seg_end) + sum_m w_m B_m x_m^T
    w = jnp.exp(seg_end - cum) * dt          # (L,)
    inject = jax.lax.dot_general(Bm * w[:, None], x,
                                 (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (N,P)
    state_ref[...] = state * jnp.exp(seg_end) + inject

    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        state_out_ref[0, 0] = state_ref[...]


def ssd_scan_fwd(x, dt, A, Bm, Cm, *, chunk: int = 128,
                 interpret: bool = True):
    """x: (B, H, S, P); dt: (B, H, S); A: (H,); Bm/Cm: (B, G, S, N).
    Returns (y (B, H, S, P), final state (B, H, N, P))."""
    B, H, S, P = x.shape
    G, N = Bm.shape[1], Bm.shape[3]
    rep = H // G
    assert S % chunk == 0
    n_chunks = S // chunk

    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        kernel,
        grid=(B, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda b, h, c: (b, h // rep, c, 0)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda b, h, c: (b, h // rep, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
