"""Gradient compression for slow (cross-pod / disaggregated) links.

The paper's ExpEther measurements show disaggregated links at ~20% of local
bandwidth; the analogous pressure point here is the cross-pod `pod` axis of
the DP all-reduce. int8 stochastic-free symmetric quantization with
per-tensor scale + error feedback keeps the compressed all-reduce unbiased
in the long run while cutting pod-axis bytes 4x vs fp32 (2x vs bf16).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_gradients(grads, error_state=None):
    """Quantize every leaf to int8 with error feedback.

    Returns (decompressed grads to feed the all-reduce path, new error
    state). On real hardware the int8 payload is what crosses the pod axis;
    in the dry-run the quantize/dequantize pair shows up in the HLO and the
    collective operand dtype shrinks accordingly when enabled end-to-end.
    """
    if error_state is None:
        error_state = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), gf - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = treedef.unflatten([o[0] for o in out])
    new_e = treedef.unflatten([o[1] for o in out])
    return new_g, new_e
