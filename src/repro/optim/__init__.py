from repro.optim.adamw import AdamW, OptState  # noqa: F401
from repro.optim.schedule import cosine_schedule  # noqa: F401
from repro.optim.compression import compress_gradients  # noqa: F401
