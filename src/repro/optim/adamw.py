"""AdamW with global-norm clipping — self-contained (no optax).

Moments are stored fp32 and shard exactly like the parameters (the sharding
policy's FSDP rules apply to the whole train state), which is what makes the
235B MoE fit: params + moments + grads are all 256-way sharded.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array  # () int32
    mu: dict
    nu: dict


class AdamW:
    def __init__(self, lr=3e-4, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1, clip_norm=1.0, schedule=None):
        self.lr = lr
        self.b1, self.b2, self.eps = b1, b2, eps
        self.weight_decay = weight_decay
        self.clip_norm = clip_norm
        self.schedule = schedule

    def init(self, params) -> OptState:
        def zeros(t):
            return jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), t)
        return OptState(step=jnp.zeros((), jnp.int32), mu=zeros(params),
                        nu=zeros(params))

    def update(self, grads, state: OptState, params):
        """Returns (new_params, new_state, metrics)."""
        step = state.step + 1
        gnorm = global_norm(grads)
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)

        lr = self.lr if self.schedule is None else self.schedule(step)
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mh = m / c1
            vh = v / c2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * delta
            return new_p.astype(p.dtype), m, v

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        out = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        metrics = {"grad_norm": gnorm,
                   "lr": jnp.asarray(lr, jnp.float32)}
        return new_p, OptState(step=step, mu=new_m, nu=new_v), metrics


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)))
                        for leaf in leaves))
