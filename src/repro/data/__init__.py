from repro.data.pipeline import SyntheticLMDataset, make_data_iterator  # noqa: F401
