"""Deterministic, shardable synthetic data pipeline.

Reproduces the shape of a production token pipeline: a seeded stream of
(tokens,) batches, resumable from an arbitrary step (checkpoint/restart
resumes the stream exactly), sharded placement onto the slice's mesh, and a
host-side prefetch queue that overlaps batch synthesis with device compute.

A Zipf-ish token distribution (rather than uniform) keeps the embedding
gather access pattern and loss magnitudes realistic. For the paper's MNIST /
ImageNet analogues, see benchmarks/ — the LM stream is the payload workload
for the assigned architectures.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


class PipelineQueue:
    """Bounded stage-boundary queue — the prefetch pattern of
    ``make_data_iterator`` factored out so the meta-accelerator data plane
    (core/meta_accel.py, DESIGN.md §5) can join its hop/compute workers
    with the same machinery.

    Semantics: blocking bounded handoff, ``close()`` terminates the
    consumer after in-flight items drain, and every put/get watches a
    shared stop event so no producer or consumer thread is ever stranded
    on a peer that died (error paths call ``stop()``)."""

    CLOSE = object()

    def __init__(self, maxsize: int = 2,
                 stop: Optional[threading.Event] = None):
        self._q: "queue.Queue" = queue.Queue(maxsize=maxsize)
        self.stop_event = stop if stop is not None else threading.Event()

    def put(self, item) -> bool:
        """Blocking put. Returns False (item dropped) once stopped."""
        while not self.stop_event.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def close(self):
        """End-of-stream: consumers finish after draining queued items."""
        self.put(PipelineQueue.CLOSE)

    def stop(self):
        """Abort both sides immediately (error / cleanup path)."""
        self.stop_event.set()

    def __iter__(self):
        while not self.stop_event.is_set():
            try:
                item = self._q.get(timeout=0.05)
            except queue.Empty:
                continue
            if item is PipelineQueue.CLOSE:
                return
            yield item


class SyntheticLMDataset:
    """Seeded, random-access synthetic LM token stream."""

    def __init__(self, cfg: ModelConfig, seq_len: int, global_batch: int,
                 seed: int = 0):
        self.cfg = cfg
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        # Zipf-ish unnormalized weights over a capped alphabet
        vocab = min(cfg.vocab_size, 32_768)
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._vocab = vocab

    def batch(self, step: int) -> dict:
        """Random-access batch synthesis — resumable at any step."""
        rng = np.random.default_rng((self.seed, step))
        tokens = rng.choice(self._vocab, p=self._probs,
                            size=(self.global_batch, self.seq_len))
        out = {"tokens": tokens.astype(np.int32)}
        if self.cfg.family == "audio":
            out["frames"] = rng.standard_normal(
                (self.global_batch, self.cfg.encoder_seq,
                 self.cfg.d_model)).astype(np.float32) * 0.02
        if self.cfg.family == "vlm":
            out["vision_embeds"] = rng.standard_normal(
                (self.global_batch, self.cfg.n_vision_patches,
                 self.cfg.d_model)).astype(np.float32) * 0.02
        return out


def make_data_iterator(dataset: SyntheticLMDataset, start_step: int = 0,
                       shardings=None, prefetch: int = 2,
                       stop_step: Optional[int] = None) -> Iterator[dict]:
    """Prefetching iterator; places batches with the given shardings."""

    def produce(step):
        host = dataset.batch(step)
        if shardings is None:
            return {k: jnp.asarray(v) for k, v in host.items()}
        return {k: jax.device_put(v, shardings[k])
                if k in shardings else jnp.asarray(v)
                for k, v in host.items()}

    pq = PipelineQueue(maxsize=prefetch)

    def worker():
        step = start_step
        while not pq.stop_event.is_set():
            if stop_step is not None and step >= stop_step:
                pq.close()
                return
            if not pq.put((step, produce(step))):
                return
            step += 1

    t = threading.Thread(target=worker, daemon=True)
    t.start()

    def gen():
        try:
            for item in pq:
                yield item
        finally:
            pq.stop()

    return gen()
