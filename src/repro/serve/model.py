"""Paged-cache reference LM for the serving plane (DESIGN.md §10).

A deliberately small GQA transformer whose decode path reads KV through
the paged block pool: the per-step attention is
``kernels/paged_attention.py`` (Pallas on TPU, jnp twin on CPU), and new
K/V land directly in pool pages via a scatter at the lane's
``(write_page, write_offset)`` slot. It exists so the continuous-batching
engine's scheduling claims are measured against a real autoregressive
decode — token t+1's inputs depend on token t through the cache — rather
than a sleep-based stand-in, while staying small enough that CPU CI runs
thousands of steps.

Every per-lane computation is row-independent (embedding lookup, per-row
matmuls, per-row masked softmax over that row's own pages), which is the
property that makes continuous batching *bit-identical* per request to
static batching — the scheduler can't change anyone's tokens, only when
they are computed. tests/test_serve_engine.py pins this.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention import (paged_attention_jnp,
                                           paged_decode_attention_fwd)


@dataclasses.dataclass(frozen=True)
class LMConfig:
    vocab: int = 128
    d_model: int = 32
    n_heads: int = 4
    n_kv_heads: int = 2
    head_dim: int = 8
    n_layers: int = 2
    page_size: int = 8
    window: Optional[int] = None
    softcap: Optional[float] = None
    rope_base: float = 10000.0
    norm_eps: float = 1e-6


def init(cfg: LMConfig, key) -> dict:
    ks = jax.random.split(key, 6)
    d, L = cfg.d_model, cfg.n_layers
    dq = cfg.n_heads * cfg.head_dim
    dkv = cfg.n_kv_heads * cfg.head_dim

    def w(k, shape, fan_in):
        return jax.random.normal(k, shape) / jnp.sqrt(fan_in)

    return {
        "embed": jax.random.normal(ks[0], (cfg.vocab, d)) * 0.5,
        "wq": w(ks[1], (L, d, dq), d),
        "wkv": w(ks[2], (L, d, 2 * dkv), d),
        "wo": w(ks[3], (L, dq, d), dq),
        "w1": w(ks[4], (L, d, 2 * d), d),
        "w2": w(ks[5], (L, 2 * d, d), 2 * d),
    }


def _norm(cfg: LMConfig, x):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + cfg.norm_eps)


def _rope(cfg: LMConfig, x, pos):
    """x: (..., S, H, Dh); pos: broadcastable to (..., S)."""
    half = cfg.head_dim // 2
    freqs = cfg.rope_base ** (-jnp.arange(half) / half)
    ang = pos[..., None].astype(jnp.float32) * freqs      # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                      # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x1 * sin + x2 * cos], -1).astype(x.dtype)


def _qkv(cfg: LMConfig, params, layer, xn, pos):
    """xn: (B, S, d) normed activations; pos broadcastable to (B, S).
    Returns roped q (B, S, Hq, Dh), k, v (B, S, Hkv, Dh)."""
    B, S, _ = xn.shape
    q = (xn @ params["wq"][layer]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    kv = xn @ params["wkv"][layer]
    k, v = jnp.split(kv, 2, axis=-1)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    return _rope(cfg, q, pos), _rope(cfg, k, pos), v


def _mlp(cfg: LMConfig, params, layer, x):
    h = jax.nn.silu(_norm(cfg, x) @ params["w1"][layer])
    return x + h @ params["w2"][layer]


def decode_step(cfg: LMConfig, params, k_pages, v_pages, tokens,
                page_table, kv_len, write_page, write_off, *,
                use_pallas: bool = False):
    """One token per lane against the paged pool.

    tokens: (B,) int32 input token per lane (a prompt token while the
    lane prefills, the previous output while it decodes); page_table:
    (B, max_pages) int32; kv_len: (B,) tokens held *before* this step;
    write_page/write_off: (B,) slot where this token's K/V land (the
    null page 0 for inactive lanes). Returns (next_token (B,), logits
    (B, V), k_pages, v_pages)."""
    x = params["embed"][tokens][:, None, :]               # (B, 1, d)
    pos = kv_len[:, None]                                 # (B, 1)
    for layer in range(cfg.n_layers):
        xn = _norm(cfg, x)
        q, k_new, v_new = _qkv(cfg, params, layer, xn, pos)
        # land this token's K/V in its pool slot; inactive lanes all hit
        # the null page, where last-write-wins garbage is never read
        k_pages = k_pages.at[layer, write_page, :, write_off, :].set(
            k_new[:, 0])
        v_pages = v_pages.at[layer, write_page, :, write_off, :].set(
            v_new[:, 0])
        attn_fn = (functools.partial(paged_decode_attention_fwd,
                                     interpret=True)
                   if use_pallas else paged_attention_jnp)
        attn = attn_fn(q.transpose(0, 2, 1, 3), k_pages[layer],
                       v_pages[layer], page_table, kv_len + 1, kv_len,
                       window=cfg.window, softcap=cfg.softcap)
        attn = attn.transpose(0, 2, 1, 3).reshape(
            x.shape[0], 1, cfg.n_heads * cfg.head_dim)
        x = x + attn @ params["wo"][layer]
        x = _mlp(cfg, params, layer, x)
    logits = (_norm(cfg, x) @ params["embed"].T)[:, 0]    # (B, V)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), logits, \
        k_pages, v_pages


def prefill(cfg: LMConfig, params, prompts):
    """Full-sequence prompt pass (the disaggregated prefill stage's
    compute): prompts (b, T) int32 -> (k, v) each
    (n_layers, b, Hkv, T, Dh) post-RoPE — exactly what decode_step would
    have written token-by-token — plus last-position logits (b, V)."""
    b, T = prompts.shape
    x = params["embed"][prompts]                          # (b, T, d)
    pos = jnp.arange(T)[None, :]
    i = jnp.arange(T)
    mask = i[None, :] <= i[:, None]                       # causal (T, T)
    if cfg.window is not None:
        mask &= (i[:, None] - i[None, :]) < cfg.window
    ks, vs = [], []
    for layer in range(cfg.n_layers):
        xn = _norm(cfg, x)
        q, k, v = _qkv(cfg, params, layer, xn, pos)
        ks.append(k.transpose(0, 2, 1, 3))                # (b, Hkv, T, Dh)
        vs.append(v.transpose(0, 2, 1, 3))
        G = cfg.n_heads // cfg.n_kv_heads
        kk = jnp.repeat(ks[-1], G, axis=1).astype(jnp.float32)
        vv = jnp.repeat(vs[-1], G, axis=1).astype(jnp.float32)
        qt = q.transpose(0, 2, 1, 3).astype(jnp.float32)  # (b, Hq, T, Dh)
        s = jnp.einsum("bhsd,bhtd->bhst", qt, kk) / jnp.sqrt(
            jnp.float32(cfg.head_dim))
        if cfg.softcap is not None:
            s = jnp.tanh(s / cfg.softcap) * cfg.softcap
        s = jnp.where(mask[None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        attn = jnp.einsum("bhst,bhtd->bhsd", p, vv).astype(x.dtype)
        attn = attn.transpose(0, 2, 1, 3).reshape(
            b, T, cfg.n_heads * cfg.head_dim)
        x = x + attn @ params["wo"][layer]
        x = _mlp(cfg, params, layer, x)
    logits = _norm(cfg, x) @ params["embed"].T            # (b, T, V)
    return jnp.stack(ks), jnp.stack(vs), logits[:, -1]
