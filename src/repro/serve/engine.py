"""Continuous-batching serving engine over the paged KV pool.

The serving analogue of FlowOS-RM's event-driven scheduler: instead of
jobs onto devices, it places *sequences onto decode lanes and pages*,
re-deciding every step (DESIGN.md §10):

  * **join on arrival** — free lanes are refilled from the waiting queue
    at every step boundary, so a retiring straggler's lane is reused on
    the very next token, not when the whole batch drains;
  * **retire on completion** — a sequence leaves (EOS / token budget) and
    its pages merge back into the pool's free runs immediately;
  * **preempt-to-recompute on page exhaustion** — when a growing sequence
    cannot get a page, the youngest sequence is evicted: pages freed, its
    prompt + tokens-so-far re-queued as a recompute (greedy decode makes
    the continuation bit-identical), mirroring FlowOS-RM's
    checkpoint-preempt protocol with "checkpoint" = the token history.

The decode step itself runs at a *fixed lane count* — one compiled
executable for the whole run, no retrace as sequences come and go; lanes
without a sequence write to the null page and their outputs are ignored.
Prompts stream through the same step function one token per lane per
step (chunked prefill), so prefill tokens of a joining request overlap
in-flight decode of every other lane — the token-level analogue of the
PR 2 microbatch overlap. Alternatively ``ingest_prefill`` admits a
request whose prompt KV was computed by a *disaggregated prefill stage*
(launch/serve.py wires this through the PR 2 MetaAccelerator hop).

``mode="static"`` is the baseline this PR retires: admission only when
every lane is free, i.e. the whole batch drains at straggler speed.
"""
from __future__ import annotations

import dataclasses
import enum
import functools
import itertools
from collections import deque
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serve import model as M
from repro.serve.kv_cache import (PagedKVCache, PageExhausted,
                                  SequenceCapExceeded)


@functools.lru_cache(maxsize=None)
def _jitted_step(cfg: "M.LMConfig", use_pallas: bool):
    """One compiled decode step per (config, backend) shared by every
    engine — the static-baseline and continuous engines in one benchmark
    process must hit the same executable, not recompile per engine."""
    import jax
    return jax.jit(functools.partial(M.decode_step, cfg,
                                     use_pallas=use_pallas),
                   donate_argnums=(1, 2))


@functools.lru_cache(maxsize=None)
def _jitted_ingest():
    import jax
    return jax.jit(ContinuousEngine._scatter_prompt,
                   donate_argnums=(0, 1))


class RequestState(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (T,) int32
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    state: RequestState = RequestState.WAITING
    prefills: int = 0               # (re)prefill count: >1 => preempted


def timed_drain(engine: "ContinuousEngine", reqs) -> dict:
    """Submit, drain, and annotate the stats with wall seconds and
    generated tokens/sec — the one definition of the serving throughput
    metric, shared by the launch driver and the gated benchmark."""
    import time
    engine.submit_many(reqs)
    t0 = time.perf_counter()
    stats = engine.run()
    stats["seconds"] = time.perf_counter() - t0
    stats["tok_per_s"] = stats["generated_tokens"] / max(
        stats["seconds"], 1e-9)
    return stats


def warmup_engine(cfg: "M.LMConfig", params, *, lanes: int,
                  num_pages: int, max_pages_per_seq: int,
                  use_pallas: bool = False):
    """Compile the shared step executable at the run's exact shapes,
    outside any timed region (one trivial request streamed through)."""
    eng = ContinuousEngine(cfg, params, lanes=lanes, num_pages=num_pages,
                           max_pages_per_seq=max_pages_per_seq,
                           use_pallas=use_pallas)
    eng.submit(Request(rid=0, prompt=np.zeros(2, np.int32),
                       max_new_tokens=1))
    eng.run()


def equal_page_budget(lanes: int, prompt_len: int, max_new_cap: int,
                      page_size: int):
    """(max_pages_per_seq, num_pages) sized to what *static* batching
    would reserve for a full worst-case batch (+ the null page). The
    launch driver and the gated benchmark must share this sizing — the
    'equal HBM page budget' claim is only a pure-scheduling comparison
    if both compute it identically."""
    per_seq = -(-(prompt_len + max_new_cap + 1) // page_size)
    return per_seq, lanes * per_seq + 1


def make_zipf_requests(vocab: int, rng, n: int, prompt_len: int, *,
                       zipf_a: float = 1.8, max_new_cap: int = 64,
                       min_new: int = 1) -> List[Request]:
    """Ragged serving workload: equal prompts, Zipf-distributed response
    lengths truncated to [min_new, max_new_cap] — the many-short /
    few-very-long shape real traffic has, where a static batch drains at
    the speed of its longest member (benchmarks/serve_continuous.py)."""
    lens = np.clip(rng.zipf(zipf_a, n), min_new, max_new_cap)
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab, prompt_len).astype(
                        np.int32),
                    max_new_tokens=int(ln))
            for i, ln in enumerate(lens)]


class ContinuousEngine:
    """Fixed-lane continuous-batching scheduler over one PagedKVCache."""

    def __init__(self, cfg: M.LMConfig, params, *, lanes: int,
                 num_pages: int, max_pages_per_seq: Optional[int] = None,
                 mode: str = "continuous", use_pallas: bool = False,
                 eos_id: Optional[int] = None, slice_=None):
        if mode not in ("continuous", "static"):
            raise ValueError(f"unknown mode {mode!r}")
        self.cfg = cfg
        self.params = params
        self.mode = mode
        self.eos_id = eos_id
        self.n_lanes = lanes
        self.cache = PagedKVCache(
            num_pages=num_pages, page_size=cfg.page_size,
            n_layers=cfg.n_layers, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, max_pages_per_seq=max_pages_per_seq)
        if slice_ is not None:
            # the pool is the job's dominant long-lived HBM reservation
            slice_.account_hbm("kv_pages", self.cache.hbm_bytes)
        self._step_fn = _jitted_step(cfg, use_pallas)
        self._ingest_fn = _jitted_ingest()
        self.lanes: List[Optional[int]] = [None] * lanes
        self.waiting: deque = deque()
        self.requests: Dict[int, Request] = {}
        self._next_input: Dict[int, int] = {}
        self._cursor: Dict[int, int] = {}      # prompt tokens consumed
        self._admit_order: Dict[int, int] = {}
        self._admit_counter = itertools.count()
        self.stats = {"steps": 0, "generated_tokens": 0,
                      "prefill_tokens": 0, "ingested_tokens": 0,
                      "preemptions": 0, "admissions": 0,
                      "truncated": 0, "rejected": 0}

    # -- submission -------------------------------------------------------
    def submit(self, req: Request):
        """Join on arrival: queued now, admitted at the next step."""
        req.state = RequestState.WAITING
        self.requests[req.rid] = req
        self.waiting.append(req.rid)

    def submit_many(self, reqs: Sequence[Request]):
        for r in reqs:
            self.submit(r)

    # -- admission / eviction --------------------------------------------
    def _effective_prompt(self, req: Request) -> np.ndarray:
        """Recompute view: original prompt plus tokens generated before a
        preemption (they re-enter as prompt; greedy decode regenerates
        the identical continuation)."""
        if not req.generated:
            return req.prompt
        return np.concatenate(
            [req.prompt, np.asarray(req.generated, np.int32)])

    def _admit(self):
        if self.mode == "static" and any(s is not None for s in self.lanes):
            return                      # static: drain the batch first
        for lane in range(self.n_lanes):
            if self.lanes[lane] is not None or not self.waiting:
                continue
            rid = self.waiting[0]
            req = self.requests[rid]
            prompt = self._effective_prompt(req)
            # admission watermark: the prompt plus one decode token is
            # *reserved* atomically, so a step that admits several
            # sequences can't over-commit and joining never evicts
            # running sequences mid-prefill (decode-phase growth beyond
            # the reservation is what triggers preemption)
            try:
                self.cache.alloc_seq(rid, 0,
                                     reserve_tokens=len(prompt) + 1)
            except SequenceCapExceeded:
                # the prompt alone can never fit this pool's per-seq
                # cap: reject the request, don't wedge the queue
                self.waiting.popleft()
                req.state = RequestState.DONE
                self.stats["rejected"] += 1
                continue
            except PageExhausted:
                break               # head-of-queue blocks; FIFO holds
            self.waiting.popleft()
            self.lanes[lane] = rid
            req.state = RequestState.PREFILL
            req.prefills += 1
            self._cursor[rid] = 0
            self._next_input[rid] = int(prompt[0])
            self._admit_order[rid] = next(self._admit_counter)
            self.stats["admissions"] += 1

    def _preempt(self, rid: int):
        """Evict to the front of the queue; pages return to the pool."""
        lane = self.lanes.index(rid)
        self.cache.free_seq(rid)
        self.lanes[lane] = None
        req = self.requests[rid]
        req.state = RequestState.WAITING
        self.waiting.appendleft(rid)
        for d in (self._next_input, self._cursor, self._admit_order):
            d.pop(rid, None)
        self.stats["preemptions"] += 1

    def _make_room(self, rid: int) -> bool:
        """Get append capacity for ``rid``, evicting youngest-first until
        it fits. Returns False when ``rid`` left its lane instead: a
        sequence at the per-sequence page cap is *truncated* (retired
        with what it has — no eviction can grow it), and the requester
        itself may be the eviction victim."""
        while True:
            try:
                if self.cache.ensure_append(rid):
                    return True
            except SequenceCapExceeded:
                self._retire(rid)
                self.stats["truncated"] += 1
                return False
            active = [s for s in self.lanes if s is not None]
            victim = max(active, key=self._admit_order.__getitem__)
            if victim == rid and len(active) == 1:
                raise PageExhausted(
                    f"page budget cannot hold a single sequence "
                    f"(seq {rid} at {self.cache.seq_len(rid)} tokens, "
                    f"{self.cache.free_pages} pages free)")
            self._preempt(victim)
            if victim == rid:
                return False

    def _retire(self, rid: int):
        lane = self.lanes.index(rid)
        self.cache.free_seq(rid)
        self.lanes[lane] = None
        self.requests[rid].state = RequestState.DONE
        for d in (self._next_input, self._cursor, self._admit_order):
            d.pop(rid, None)

    # -- the step ---------------------------------------------------------
    def step(self) -> bool:
        """Admit, make page room, run one fused lane-batch token step,
        and account the outcome per lane. Returns False when idle."""
        import jax.numpy as jnp

        self._admit()
        if all(s is None for s in self.lanes):
            return False
        for lane in range(self.n_lanes):
            rid = self.lanes[lane]
            if rid is not None:
                self._make_room(rid)
        B = self.n_lanes
        tokens = np.zeros(B, np.int32)
        write_page = np.zeros(B, np.int32)
        write_off = np.zeros(B, np.int32)
        for lane, rid in enumerate(self.lanes):
            if rid is None:
                continue
            tokens[lane] = self._next_input[rid]
            write_page[lane], write_off[lane] = self.cache.write_slot(rid)
        table = self.cache.page_table(self.lanes)
        kv_len = self.cache.kv_lens(self.lanes)
        next_tok, _logits, self.cache.k, self.cache.v = self._step_fn(
            self.params, self.cache.k, self.cache.v, jnp.asarray(tokens),
            jnp.asarray(table), jnp.asarray(kv_len),
            jnp.asarray(write_page), jnp.asarray(write_off))
        next_tok = np.asarray(next_tok)
        self.stats["steps"] += 1
        for lane, rid in enumerate(self.lanes):
            if rid is None:
                continue
            self.cache.advance(rid)
            req = self.requests[rid]
            if req.state is RequestState.PREFILL:
                prompt = self._effective_prompt(req)
                self.stats["prefill_tokens"] += 1
                self._cursor[rid] += 1
                if self._cursor[rid] < len(prompt):
                    self._next_input[rid] = int(prompt[self._cursor[rid]])
                    continue
                req.state = RequestState.DECODE
                # a recomputed sequence re-emits nothing: its "first"
                # tokens already sit in req.generated
                if len(req.generated) >= req.max_new_tokens:
                    self._retire(rid)
                    continue
            self._append_token(rid, int(next_tok[lane]))
        return True

    def _append_token(self, rid: int, tok: int):
        req = self.requests[rid]
        req.generated.append(tok)
        self.stats["generated_tokens"] += 1
        self._next_input[rid] = tok
        if (len(req.generated) >= req.max_new_tokens
                or (self.eos_id is not None and tok == self.eos_id)):
            self._retire(rid)

    # -- disaggregated-prefill ingestion ----------------------------------
    @staticmethod
    def _scatter_prompt(k_pages, v_pages, k, v, page_ids):
        """k, v: (L, Hkv, T, Dh) one sequence's prompt KV; page_ids:
        (n,) with n*page_size >= T. Pads T up to whole pages and lands
        them in the pool in one scatter."""
        import jax.numpy as jnp
        L, Hkv, T, Dh = k.shape
        n = page_ids.shape[0]
        ps = k_pages.shape[3]
        pad = n * ps - T

        def blocks(x):
            x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
            x = x.reshape(L, Hkv, n, ps, Dh)
            return x.transpose(0, 2, 1, 3, 4)     # (L, n, Hkv, ps, Dh)

        k_pages = k_pages.at[:, page_ids].set(blocks(k))
        v_pages = v_pages.at[:, page_ids].set(blocks(v))
        return k_pages, v_pages

    def ingest_prefill(self, req: Request, k, v, last_logits):
        """Admit a request whose prompt KV arrived from a disaggregated
        prefill stage (the PR 2 fabric hop): allocate pages, scatter the
        KV in, and enter DECODE directly — no prompt streaming. Requires
        a free lane (the caller steps the engine until one frees)."""
        import jax.numpy as jnp

        if None not in self.lanes:
            raise RuntimeError("no free lane; step() until one retires")
        T = len(req.prompt)
        rid = req.rid
        # the reservation covers the first decode token too, so the
        # eviction loop — not a crash — handles the exactly-full case;
        # the request is registered only once pages are secured (an
        # allocation failure must not leak a phantom requests entry)
        while True:
            try:
                self.cache.alloc_seq(rid, T, reserve_tokens=T + 1)
                break
            except PageExhausted:
                active = [s for s in self.lanes if s is not None]
                if not active:
                    raise
                self._preempt(max(active,
                                  key=self._admit_order.__getitem__))
        self.requests[rid] = req
        lane = self.lanes.index(None)
        self.lanes[lane] = rid
        page_ids = jnp.asarray(
            self.cache.seq_pages(rid)[:self.cache.pages_for(T)],
            jnp.int32)
        self.cache.k, self.cache.v = self._ingest_fn(
            self.cache.k, self.cache.v, k, v, page_ids)
        req.state = RequestState.DECODE
        req.prefills += 1
        self._cursor[rid] = T
        self._admit_order[rid] = next(self._admit_counter)
        self.stats["ingested_tokens"] += T
        self.stats["admissions"] += 1
        self._append_token(rid, int(np.argmax(np.asarray(last_logits))))

    # -- driver ------------------------------------------------------------
    def run(self) -> dict:
        """Drain everything submitted so far; returns the stats dict."""
        while True:
            if not self.step():
                # step() already tried admission into an all-free engine;
                # anything still waiting can never fit
                if self.waiting:
                    raise PageExhausted(
                        "waiting requests cannot be admitted into an "
                        "empty engine — page budget too small")
                return dict(self.stats)
