from repro.serve.kv_cache import (PagedKVCache,  # noqa: F401
                                  PageExhausted, SequenceCapExceeded)
from repro.serve.engine import (ContinuousEngine, Request,  # noqa: F401
                                RequestState, equal_page_budget,
                                make_zipf_requests, timed_drain,
                                warmup_engine)
from repro.serve.model import LMConfig  # noqa: F401
