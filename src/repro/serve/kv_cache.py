"""Paged KV-cache block pool: the free-run allocator moves into HBM.

One HBM block pool holds every sequence's KV state in fixed-size pages
(page_size tokens x Hkv x head_dim, per layer); a sequence owns an ordered
page list — its page table — and the paged decode kernel
(kernels/paged_attention.py) gathers through it. Static per-sequence
``max_len`` over-allocation (the monolithic provisioning the paper argues
disaggregation eliminates) becomes pay-per-page: a sequence holds
``ceil(len / page_size)`` pages, never more.

Page ids are placed by the *same* ``FreeRunIndex`` that places
accelerators in the fabric pool (core/pool.py, DESIGN.md §3) — one
allocator abstraction for devices in the fabric and pages in HBM, and the
index's O(log n) merge/split + best-fit invariants carry over unchanged
(tests/test_serve_engine.py re-runs the invariant suite at page-sized
configurations). Best-fit keeps a sequence's pages as contiguous as the
pool allows, which on TPU turns the page gather into fewer, longer DMAs.

Page 0 is reserved as the **null page**: padded page-table slots and
masked (inactive-lane) writes land there, so every table slot is always a
valid page id — the kernel prefetches a block's page before the kv_len
mask is known. The null page is never allocated to a sequence.

The arrays themselves (``k``, ``v``) are functional jax values: jitted
step functions return updated pools and the engine swaps them in; this
class owns only the *placement* metadata (who holds which page).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.pool import FreeRunIndex

# all pages live in one bucket: a single HBM "pod" of kind "page"
_BUCKET = (0, "page")


class PageExhausted(RuntimeError):
    """The pool cannot serve the allocation *right now*; the engine's
    response is preempt-to-recompute (DESIGN.md §10), not a crash."""


class SequenceCapExceeded(RuntimeError):
    """The sequence itself exceeds ``max_pages_per_seq`` — a property of
    the request, not of pool pressure: no eviction can fix it, so the
    engine must truncate/reject that sequence rather than preempt
    innocent neighbours."""


class PagedKVCache:
    """Placement metadata + backing arrays for one paged KV block pool."""

    def __init__(self, *, num_pages: int, page_size: int, n_layers: int,
                 n_kv_heads: int, head_dim: int, dtype=None,
                 max_pages_per_seq: Optional[int] = None):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the null page)")
        import jax.numpy as jnp
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_pages_per_seq = (max_pages_per_seq
                                  if max_pages_per_seq is not None
                                  else num_pages - 1)
        self._index = FreeRunIndex()
        self._index.add_range(_BUCKET, 1, num_pages)   # 0 = null page
        self._pages: Dict[int, List[int]] = {}          # seq -> page ids
        self._len: Dict[int, int] = {}                  # seq -> tokens held
        dtype = dtype if dtype is not None else jnp.float32
        shape = (n_layers, num_pages, n_kv_heads, page_size, head_dim)
        self.k = jnp.zeros(shape, dtype)
        self.v = jnp.zeros(shape, dtype)

    # -- pool-level queries ----------------------------------------------
    @property
    def free_pages(self) -> int:
        return self._index.free_count("page")

    @property
    def used_pages(self) -> int:
        return (self.num_pages - 1) - self.free_pages

    @property
    def hbm_bytes(self) -> int:
        """Bytes of HBM the block pool pins (what the owning slice
        accounts via ``Slice.account_hbm``)."""
        return self.k.nbytes + self.v.nbytes

    def fragmentation(self) -> float:
        free = self._index.free_count("page")
        if free <= 0:
            return 0.0
        return 1.0 - self._index.largest_run("page") / free

    def free_runs(self):
        return self._index.snapshot().get(_BUCKET, [])

    # -- per-sequence placement ------------------------------------------
    def _take(self, n: int) -> List[int]:
        """Allocate n page ids: best-fit contiguous when a run exists,
        lowest-id spill across runs otherwise (same policy ladder as
        DevicePool.acquire)."""
        if self._index.free_count("page") < n:
            raise PageExhausted(
                f"need {n} pages, {self._index.free_count('page')} free")
        run = self._index.best_fit(n, "page")
        if run is not None:
            start = run[0]
            self._index.remove_range(_BUCKET, start, start + n)
            return list(range(start, start + n))
        ids: List[int] = []
        for rs, re in self._index.runs_ascending("page"):
            take = min(n - len(ids), re - rs)
            ids.extend(range(rs, rs + take))
            if len(ids) == n:
                break
        for rs, re in _spans(ids):
            self._index.remove_range(_BUCKET, rs, re)
        return ids

    def _give_back(self, ids: Sequence[int]):
        for rs, re in _spans(sorted(ids)):
            self._index.add_range(_BUCKET, rs, re)

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size) if n_tokens > 0 else 0

    def alloc_seq(self, seq_id: int, n_tokens: int = 0,
                  reserve_tokens: int = 0):
        """Admit a sequence holding ``n_tokens`` (its prompt length when
        prefill KV is ingested in one shot; 0 when tokens stream in).
        ``reserve_tokens`` pre-allocates pages for tokens that will be
        written over the coming steps (a streaming prefill's prompt), so
        admission is atomic: either the whole reservation fits in free
        pages *now*, or PageExhausted — a joining sequence can never pass
        an availability check that a sibling admitted the same step
        already consumed."""
        if seq_id in self._pages:
            raise KeyError(f"seq {seq_id} already allocated")
        need = self.pages_for(max(n_tokens, reserve_tokens))
        if need > self.max_pages_per_seq:
            raise SequenceCapExceeded(
                f"seq needs {need} pages > max_pages_per_seq="
                f"{self.max_pages_per_seq}")
        self._pages[seq_id] = self._take(need) if need else []
        self._len[seq_id] = n_tokens

    def ensure_append(self, seq_id: int) -> bool:
        """Make room for one more token: allocates a fresh page when the
        sequence's last page is full. False (state untouched) when the
        *pool* is exhausted — the caller preempts somebody and retries.
        Raises SequenceCapExceeded when the sequence itself is at
        ``max_pages_per_seq``: eviction cannot help, the caller must
        truncate or reject this sequence."""
        pages = self._pages[seq_id]
        if self._len[seq_id] < len(pages) * self.page_size:
            return True
        if len(pages) >= self.max_pages_per_seq:
            raise SequenceCapExceeded(
                f"seq {seq_id} at max_pages_per_seq="
                f"{self.max_pages_per_seq}")
        try:
            pages.extend(self._take(1))
        except PageExhausted:
            return False
        return True

    def advance(self, seq_id: int, n: int = 1):
        """Record ``n`` tokens written (capacity must already exist)."""
        new_len = self._len[seq_id] + n
        assert new_len <= len(self._pages[seq_id]) * self.page_size, (
            f"seq {seq_id}: advance past allocated pages")
        self._len[seq_id] = new_len

    def free_seq(self, seq_id: int):
        """Retire (or evict) a sequence; its pages merge back into runs."""
        self._give_back(self._pages.pop(seq_id))
        del self._len[seq_id]

    def seq_len(self, seq_id: int) -> int:
        return self._len[seq_id]

    def seq_pages(self, seq_id: int) -> List[int]:
        return list(self._pages[seq_id])

    def has_seq(self, seq_id: int) -> bool:
        return seq_id in self._pages

    # -- kernel-facing views ---------------------------------------------
    def write_slot(self, seq_id: int) -> tuple:
        """(page_id, offset) where the sequence's *next* token lands."""
        pos = self._len[seq_id]
        pages = self._pages[seq_id]
        return pages[pos // self.page_size], pos % self.page_size

    def page_table(self, seq_ids: Sequence[Optional[int]],
                   max_pages: Optional[int] = None) -> np.ndarray:
        """(B, max_pages) int32 table for a batch of lanes; None lanes
        and slots past a sequence's pages pad with the null page 0."""
        if max_pages is None:
            max_pages = self.max_pages_per_seq
        out = np.zeros((len(seq_ids), max_pages), np.int32)
        for i, sid in enumerate(seq_ids):
            if sid is None:
                continue
            ids = self._pages[sid]
            if len(ids) > max_pages:
                raise ValueError(f"seq {sid} holds {len(ids)} pages > "
                                 f"table width {max_pages}")
            out[i, :len(ids)] = ids
        return out

    def kv_lens(self, seq_ids: Sequence[Optional[int]]) -> np.ndarray:
        """(B,) int32 live lengths; None lanes are 0."""
        return np.array([0 if sid is None else self._len[sid]
                         for sid in seq_ids], np.int32)


def _spans(ids: Sequence[int]):
    """Maximal contiguous [start, end) spans of an ascending id list."""
    spans = []
    for i in ids:
        if spans and spans[-1][1] == i:
            spans[-1][1] = i + 1
        else:
            spans.append([i, i + 1])
    return [(a, b) for a, b in spans]
