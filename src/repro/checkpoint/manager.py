"""Checkpoint/restart with async save and elastic re-shard on restore.

Design (mirrors what a multi-host Orbax deployment does, self-contained):

* ``save`` snapshots the train state to host memory synchronously (cheap —
  device-to-host DMA) and writes to disk on a background thread, so the
  training loop resumes immediately (async checkpointing).
* Atomicity: writes go to ``step_<n>.tmp/`` and are renamed only when
  complete; a crash mid-write never corrupts the latest checkpoint.
* ``restore`` takes target shardings: the slice shape at restore time may
  differ from the shape at save time (elastic rescale / failure recovery —
  FlowOS-RM rebuilds the slice and the state re-shards onto the new mesh).
* Retention: keeps the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import pickle
import shutil
import threading
from typing import Any, Optional

import numpy as np

import jax


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any, blocking: bool = False):
        """Async save: snapshot to host, write on a background thread."""
        self.wait()  # at most one in-flight save
        host_state = jax.tree.map(np.asarray, jax.device_get(state))

        def write():
            try:
                tmp = os.path.join(self.directory, f"step_{step}.tmp")
                final = os.path.join(self.directory, f"step_{step}")
                os.makedirs(tmp, exist_ok=True)
                leaves, treedef = jax.tree.flatten(host_state)
                np.savez(os.path.join(tmp, "leaves.npz"),
                         **{f"leaf_{i}": leaf
                            for i, leaf in enumerate(leaves)})
                with open(os.path.join(tmp, "treedef.pkl"), "wb") as f:
                    pickle.dump(treedef, f)
                with open(os.path.join(tmp, "meta.json"), "w") as f:
                    json.dump({"step": step, "n_leaves": len(leaves)}, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return max(steps) if steps else None

    def restore(self, step: Optional[int] = None, shardings=None) -> Any:
        """Restore state; re-shard onto ``shardings`` if given (elastic)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(path, "treedef.pkl"), "rb") as f:
            treedef = pickle.load(f)
        data = np.load(os.path.join(path, "leaves.npz"))
        leaves = [data[f"leaf_{i}"] for i in range(len(data.files))]
        state = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state

    def restore_latest(self, shardings=None, default: Any = None) -> Any:
        """Restore the newest checkpoint, or ``default`` when none exists.
        The resume entry point for preempted/relocated tasks (FlowOS-RM
        requeues them with a fresh slice): a first run starts from
        ``default``, a re-run picks up the state the preemption saved."""
        self.wait()
        if self.latest_step() is None:
            return default
        return self.restore(shardings=shardings)

    # ------------------------------------------------------------------
    def _gc(self):
        steps = sorted(s for s in (self._all_steps()))
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    def _all_steps(self):
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    yield int(name.split("_")[1])
                except ValueError:
                    pass
